//! Temp directories for tests and benches (tempfile substitute), plus
//! the shared write-then-rename atomic-file helpers.
//!
//! Three subsystems used to carry private copies of the same
//! tmp-suffix + rename dance (`lfs/store.rs` puts, `lfs/server.rs`
//! pack caches, `lfs/http.rs` partial persistence); they now share
//! [`unique_sibling`] / [`write_atomic`] so the concurrency-safety
//! argument lives in one place: a per-process atomic sequence plus the
//! pid makes every writer's temp path unique, so no two writers can
//! rename each other's half-written file into place, and `rename` onto
//! the final path is atomic on POSIX filesystems.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// Per-process sequence for [`unique_sibling`] temp names.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A temp path next to `path`, unique to this (process, call): the
/// write half of every write-then-rename in the tree. Siblings (same
/// directory) so the final `rename` never crosses a filesystem.
pub fn unique_sibling(path: &Path) -> PathBuf {
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    path.with_extension(format!("tmp{}-{seq}", std::process::id()))
}

/// Delete regular files directly under `dir` whose name passes
/// `filter` and whose mtime is at least `ttl` old (unreadable metadata
/// counts as stale). Returns how many files were removed; a missing
/// directory is a clean zero.
///
/// The one age-based reaper for *rebuildable* staging/cache state —
/// server pack caches, client claim/spill litter. Never point it at
/// the only copy of anything.
pub fn reap_older_than(
    dir: &Path,
    ttl: std::time::Duration,
    filter: impl Fn(&str) -> bool,
) -> usize {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return 0,
    };
    let mut removed = 0;
    for entry in entries.flatten() {
        if !filter(&entry.file_name().to_string_lossy()) {
            continue;
        }
        let meta = match entry.metadata() {
            Ok(m) => m,
            Err(_) => continue,
        };
        if !meta.is_file() {
            continue;
        }
        let stale = match meta.modified().ok().and_then(|t| t.elapsed().ok()) {
            Some(age) => age >= ttl,
            // Unreadable or future mtime: treat as stale (the state is
            // rebuildable by contract).
            None => true,
        };
        if stale && std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Atomically install `bytes` at `path` (write to a unique sibling
/// temp file, then rename). Creates parent directories. A crash never
/// leaves a torn file at `path`, and concurrent writers of the same
/// path each complete their own rename.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = unique_sibling(path);
    std::fs::write(&tmp, bytes)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// A directory deleted on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh directory under the system temp dir.
    pub fn new(prefix: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("theta-{prefix}-{pid}-{n}-{nanos}"));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A path inside the directory.
    pub fn join(&self, rel: &str) -> PathBuf {
        self.path.join(rel)
    }

    /// Release ownership without deleting (for debugging).
    pub fn keep(mut self) -> PathBuf {
        let p = std::mem::take(&mut self.path);
        std::mem::forget(self);
        p
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        if !self.path.as_os_str().is_empty() {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let path;
        {
            let td = TempDir::new("t").unwrap();
            path = td.path().to_path_buf();
            std::fs::write(td.join("x"), b"hello").unwrap();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn distinct_dirs() {
        let a = TempDir::new("t").unwrap();
        let b = TempDir::new("t").unwrap();
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn unique_siblings_never_collide() {
        let target = Path::new("/some/dir/file");
        let a = unique_sibling(target);
        let b = unique_sibling(target);
        assert_ne!(a, b);
        assert_eq!(a.parent(), target.parent());
    }

    #[test]
    fn write_atomic_installs_and_overwrites() {
        let td = TempDir::new("atomic").unwrap();
        let path = td.join("nested/dir/file.bin");
        write_atomic(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        // No temp litter left behind.
        let entries: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .collect();
        assert_eq!(entries.len(), 1);
    }
}
