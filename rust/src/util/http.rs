//! Minimal dependency-free HTTP/1.1 codec over `std::net`.
//!
//! The offline vendor set has no hyper/reqwest, so the HTTP remote
//! backend (`lfs/http.rs`, `lfs/server.rs`) and the fault-injection
//! proxy (`lfs/faults.rs`) share this hand-rolled request/response
//! codec. It deliberately supports only the slice the wire protocol
//! needs: one request per connection (`Connection: close`),
//! `Content-Length`-framed bodies, and byte-exact visibility into
//! *partial* bodies — a transfer cut mid-flight must surface the bytes
//! that did arrive (for resume persistence), not an opaque error.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Largest accepted head (request/status line + headers).
const MAX_HEAD_BYTES: usize = 64 * 1024;

/// Largest accepted `Content-Length` (matches the pack format's
/// per-object ceiling; a pack can legitimately be large).
const MAX_BODY_BYTES: u64 = 1 << 33;

/// Read/write timeout applied to every transport socket.
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// An HTTP request (client side builds one, server side parses one).
#[derive(Debug, Clone)]
pub struct Request {
    /// Request method (`GET`, `PUT`, ...), uppercase.
    pub method: String,
    /// Request target: path plus optional `?query`.
    pub target: String,
    /// Additional headers, lowercase names. `content-length` and
    /// `connection` are managed by the codec.
    pub headers: Vec<(String, String)>,
    /// Request body.
    pub body: Vec<u8>,
}

impl Request {
    /// Build a body-less request.
    pub fn new(method: &str, target: &str) -> Request {
        Request {
            method: method.to_string(),
            target: target.to_string(),
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    /// Attach a header (builder style).
    pub fn header(mut self, name: &str, value: &str) -> Request {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }

    /// Attach a body (builder style).
    pub fn body(mut self, body: Vec<u8>) -> Request {
        self.body = body;
        self
    }

    /// Case-insensitive header lookup.
    pub fn get_header(&self, name: &str) -> Option<&str> {
        header_value(&self.headers, name)
    }

    /// The target's path component (before any `?`).
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// The target's query string (after `?`), if any.
    pub fn query(&self) -> Option<&str> {
        self.target.split_once('?').map(|(_, q)| q)
    }
}

/// An HTTP response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// Headers, lowercase names (`content-length` is codec-managed).
    pub headers: Vec<(String, String)>,
    /// Response body — possibly truncated; check [`Response::complete`].
    pub body: Vec<u8>,
    /// Whether the body arrived complete per its `Content-Length`.
    /// `false` means the connection died mid-body; `body` holds the
    /// prefix that made it through (resume fodder).
    pub complete: bool,
}

impl Response {
    /// Build an empty response with a status code.
    pub fn new(status: u16) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: Vec::new(),
            complete: true,
        }
    }

    /// Attach a header (builder style).
    pub fn header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }

    /// Attach a body (builder style).
    pub fn body(mut self, body: Vec<u8>) -> Response {
        self.body = body;
        self
    }

    /// Case-insensitive header lookup.
    pub fn get_header(&self, name: &str) -> Option<&str> {
        header_value(&self.headers, name)
    }
}

/// Case-insensitive header lookup over a parsed header list.
pub fn header_value<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

/// Extract `host:port` from an `http://` URL (port defaults to 80).
pub fn authority_of(url: &str) -> Result<String> {
    let rest = url
        .strip_prefix("http://")
        .with_context(|| format!("'{url}' is not an http:// URL"))?;
    let authority = rest.split('/').next().unwrap_or(rest);
    if authority.is_empty() {
        bail!("'{url}' has no host");
    }
    if authority.contains(':') {
        Ok(authority.to_string())
    } else {
        Ok(format!("{authority}:80"))
    }
}

/// Reject `http://` URLs carrying a path component. The git-theta wire
/// protocol is rooted at `/`; a path would be silently dropped and the
/// request would land on the wrong (root) remote.
pub fn require_rootless(url: &str) -> Result<()> {
    let rest = url.strip_prefix("http://").unwrap_or(url);
    if let Some((_, path)) = rest.split_once('/') {
        if !path.trim_end_matches('/').is_empty() {
            bail!(
                "'{url}' has a path component; git-theta http remotes are served at the \
                 server root (use http://host:port)"
            );
        }
    }
    Ok(())
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read a stream until the blank line ending the head. Returns the head
/// text and any body bytes that arrived in the same reads.
fn read_head(stream: &mut TcpStream) -> Result<(String, Vec<u8>)> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(pos) = find_head_end(&buf) {
            let head = String::from_utf8(buf[..pos].to_vec()).context("non-utf8 http head")?;
            return Ok((head, buf[pos + 4..].to_vec()));
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("http head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = stream.read(&mut chunk).context("reading http head")?;
        if n == 0 {
            bail!("connection closed before the http head completed");
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// Read up to `len` body bytes, starting from `leftover`. Returns the
/// bytes and whether the full declared length arrived. IO errors and
/// early EOF mid-body are reported as an incomplete body, not an error,
/// so callers can persist the prefix for a later resume.
fn read_body(stream: &mut TcpStream, leftover: Vec<u8>, len: u64) -> (Vec<u8>, bool) {
    let mut body = leftover;
    if body.len() as u64 > len {
        body.truncate(len as usize);
    }
    let mut chunk = [0u8; 65536];
    while (body.len() as u64) < len {
        match stream.read(&mut chunk) {
            Ok(0) => return (body, false),
            Ok(n) => {
                let want = (len - body.len() as u64) as usize;
                body.extend_from_slice(&chunk[..n.min(want)]);
            }
            Err(_) => return (body, false),
        }
    }
    (body, true)
}

fn parse_headers(lines: &mut std::str::Lines<'_>) -> Vec<(String, String)> {
    let mut headers = Vec::new();
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    headers
}

fn content_length(headers: &[(String, String)]) -> Result<u64> {
    let len = match header_value(headers, "content-length") {
        Some(v) => v.parse::<u64>().context("invalid content-length")?,
        None => 0,
    };
    if len > MAX_BODY_BYTES {
        bail!("declared body of {len} bytes exceeds the transport limit");
    }
    Ok(len)
}

/// Parse one request from a stream. The `bool` is body completeness —
/// `false` means the connection died mid-body (the request carries the
/// prefix that arrived, which pack uploads persist for resume).
pub fn read_request(stream: &mut TcpStream) -> Result<(Request, bool)> {
    let (head, leftover) = read_head(stream)?;
    let mut lines = head.lines();
    let start = lines.next().context("empty http request")?;
    let mut parts = start.split_whitespace();
    let method = parts.next().context("missing method")?.to_ascii_uppercase();
    let target = parts.next().context("missing request target")?.to_string();
    let headers = parse_headers(&mut lines);
    let len = content_length(&headers)?;
    let (body, complete) = read_body(stream, leftover, len);
    Ok((
        Request {
            method,
            target,
            headers,
            body,
        },
        complete,
    ))
}

/// Write a request head declaring `content_length` body bytes (which
/// the caller may then send separately — the fault proxy uses the split
/// to truncate bodies mid-flight).
pub fn write_request_head(
    stream: &mut TcpStream,
    method: &str,
    target: &str,
    headers: &[(String, String)],
    content_length: u64,
) -> Result<()> {
    let mut head = format!("{method} {target} HTTP/1.1\r\n");
    push_headers(&mut head, headers);
    head.push_str(&format!("content-length: {content_length}\r\nconnection: close\r\n\r\n"));
    stream
        .write_all(head.as_bytes())
        .context("writing http request head")
}

/// Append caller headers, skipping the codec-managed ones so relaying
/// a parsed message (the fault proxy does) never duplicates them.
fn push_headers(head: &mut String, headers: &[(String, String)]) {
    for (name, value) in headers {
        if name.eq_ignore_ascii_case("content-length") || name.eq_ignore_ascii_case("connection") {
            continue;
        }
        head.push_str(&format!("{name}: {value}\r\n"));
    }
}

/// Write a complete request.
pub fn write_request(stream: &mut TcpStream, req: &Request) -> Result<()> {
    write_request_head(
        stream,
        &req.method,
        &req.target,
        &req.headers,
        req.body.len() as u64,
    )?;
    stream
        .write_all(&req.body)
        .context("writing http request body")?;
    stream.flush().context("flushing http request")
}

fn reason_of(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        204 => "No Content",
        206 => "Partial Content",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        416 => "Range Not Satisfiable",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        _ => "Status",
    }
}

/// Write a response head declaring `content_length` body bytes.
pub fn write_response_head(
    stream: &mut TcpStream,
    status: u16,
    headers: &[(String, String)],
    content_length: u64,
) -> Result<()> {
    let mut head = format!("HTTP/1.1 {status} {}\r\n", reason_of(status));
    push_headers(&mut head, headers);
    head.push_str(&format!("content-length: {content_length}\r\nconnection: close\r\n\r\n"));
    stream
        .write_all(head.as_bytes())
        .context("writing http response head")
}

/// Write a complete response.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    write_response_head(stream, resp.status, &resp.headers, resp.body.len() as u64)?;
    stream
        .write_all(&resp.body)
        .context("writing http response body")?;
    stream.flush().context("flushing http response")
}

/// Parse one response from a stream. `head_request` suppresses body
/// reading (HEAD responses declare a length but carry no body).
pub fn read_response(stream: &mut TcpStream, head_request: bool) -> Result<Response> {
    let (head, leftover) = read_head(stream)?;
    let mut lines = head.lines();
    let start = lines.next().context("empty http response")?;
    let status = start
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .with_context(|| format!("bad http status line '{start}'"))?;
    let headers = parse_headers(&mut lines);
    if head_request {
        return Ok(Response {
            status,
            headers,
            body: Vec::new(),
            complete: true,
        });
    }
    let len = content_length(&headers)?;
    let (body, complete) = read_body(stream, leftover, len);
    Ok(Response {
        status,
        headers,
        body,
        complete,
    })
}

/// Connect, send one request, read the response (`Connection: close`).
pub fn roundtrip(authority: &str, req: &Request) -> Result<Response> {
    let mut stream = TcpStream::connect(authority)
        .with_context(|| format!("connecting to http remote {authority}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    write_request(&mut stream, req)?;
    read_response(&mut stream, req.method == "HEAD")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn authority_parsing() {
        assert_eq!(authority_of("http://127.0.0.1:8123").unwrap(), "127.0.0.1:8123");
        assert_eq!(authority_of("http://host:9/x/y").unwrap(), "host:9");
        assert_eq!(authority_of("http://host").unwrap(), "host:80");
        assert!(authority_of("file:///tmp").is_err());
        assert!(authority_of("http://").is_err());
        assert!(require_rootless("http://host:9").is_ok());
        assert!(require_rootless("http://host:9/").is_ok());
        assert!(require_rootless("http://host:9/team-a").is_err());
    }

    #[test]
    fn request_target_split() {
        let req = Request::new("GET", "/history/abc?exclude=1,2");
        assert_eq!(req.path(), "/history/abc");
        assert_eq!(req.query(), Some("exclude=1,2"));
        assert_eq!(Request::new("GET", "/x").query(), None);
    }

    #[test]
    fn roundtrip_over_real_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let (req, complete) = read_request(&mut stream).unwrap();
            assert!(complete);
            assert_eq!(req.method, "PUT");
            assert_eq!(req.path(), "/echo");
            assert_eq!(req.get_header("x-tag"), Some("t1"));
            let resp = Response::new(200).header("x-seen", "yes").body(req.body);
            write_response(&mut stream, &resp).unwrap();
        });
        let payload: Vec<u8> = (0..100_000u32).map(|x| x as u8).collect();
        let req = Request::new("PUT", "/echo").header("x-tag", "t1").body(payload.clone());
        let resp = roundtrip(&addr.to_string(), &req).unwrap();
        server.join().unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.complete);
        assert_eq!(resp.get_header("x-seen"), Some("yes"));
        assert_eq!(resp.body, payload);
    }

    #[test]
    fn truncated_body_is_reported_incomplete() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // Declare 1000 body bytes but send only 400, then drop.
            write_response_head(&mut stream, 200, &[], 1000).unwrap();
            use std::io::Write;
            stream.write_all(&[7u8; 400]).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        write_request(&mut stream, &Request::new("GET", "/partial")).unwrap();
        let resp = read_response(&mut stream, false).unwrap();
        server.join().unwrap();
        assert_eq!(resp.status, 200);
        assert!(!resp.complete);
        assert_eq!(resp.body, vec![7u8; 400]);
    }
}
