//! Minimal dependency-free HTTP/1.1 codec over `std::net`, with
//! keep-alive connection pooling and streaming bodies.
//!
//! The offline vendor set has no hyper/reqwest, so the HTTP remote
//! backend (`lfs/http.rs`, `lfs/server.rs`), the commit/ref endpoint
//! (`gitcore/remote.rs`), and the fault-injection proxy
//! (`lfs/faults.rs`) share this hand-rolled codec. It deliberately
//! supports only the slice the wire protocol needs:
//!
//! * `Content-Length`-framed bodies with **persistent connections**
//!   (HTTP/1.1 keep-alive): both peers leave the socket open after a
//!   complete exchange, so a multi-request push or fetch pays one TCP
//!   connect instead of one per request. [`HttpClient`] is the client
//!   half — a small per-endpoint pool with reconnect-on-stale
//!   fallback; the server half is the request loop in `lfs/server.rs`.
//! * **Streaming bodies**: [`read_body_to`] drains a declared body
//!   straight into any `io::Write` sink (the pack pipeline streams
//!   into files, never materializing a pack in RAM) and
//!   [`HttpClient::send_file`] streams a file region out as a request
//!   body in fixed-size chunks.
//! * Byte-exact visibility into *partial* bodies — a transfer cut
//!   mid-flight must surface the bytes that did arrive (for resume
//!   persistence), not an opaque error.

use anyhow::{bail, Context, Result};
use std::io::{Read, Seek, SeekFrom, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Largest accepted head (request/status line + headers).
const MAX_HEAD_BYTES: usize = 64 * 1024;

/// Largest accepted `Content-Length` (matches the pack format's
/// per-object ceiling; a pack can legitimately be large).
const MAX_BODY_BYTES: u64 = 1 << 33;

/// Read/write timeout applied to every transport socket.
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Chunk size for streaming body copies (socket ↔ file).
pub const COPY_CHUNK: usize = 64 * 1024;

/// Idle connections kept per [`HttpClient`] pool. Concurrent pack
/// shards can hold several connections at once; anything beyond this
/// many returning to the pool is simply closed.
const POOL_CAP: usize = 8;

/// Maximum age of an idle pooled connection before checkout discards
/// it. Kept well under the server side's [`IO_TIMEOUT`] (which closes
/// idle connections), so requests that must not be silently re-sent
/// (`PUT`s) are never handed a socket the server has probably already
/// closed.
const POOL_IDLE_MAX: Duration = Duration::from_secs(15);

/// A per-request wall-clock budget layered on the socket [`IO_TIMEOUT`].
///
/// The socket timeout alone bounds each *individual* read or write
/// call; a peer trickling one byte per interval can still pin a thread
/// indefinitely (slow-loris). A `Deadline` bounds the whole exchange:
/// before every chunk the socket timeout is re-armed to the *remaining*
/// budget (capped at [`IO_TIMEOUT`]), so the OS wakes the thread no
/// later than the deadline and the caller observes expiry as an
/// ordinary timeout.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    end: std::time::Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline {
            end: std::time::Instant::now() + budget,
        }
    }

    /// Time left before expiry (zero once expired).
    pub fn remaining(&self) -> Duration {
        self.end.saturating_duration_since(std::time::Instant::now())
    }

    /// Whether the budget is spent.
    pub fn expired(&self) -> bool {
        self.remaining().is_zero()
    }

    /// Re-arm `stream`'s read/write timeouts to the remaining budget,
    /// capped at [`IO_TIMEOUT`]. Errors once the deadline has passed,
    /// and surfaces timeout-arming failures (see [`prepare_stream`])
    /// instead of leaving the socket unbounded.
    pub fn arm(&self, stream: &TcpStream) -> Result<()> {
        let left = self.remaining();
        if left.is_zero() {
            bail!("request deadline exceeded");
        }
        let window = left.min(IO_TIMEOUT);
        stream
            .set_read_timeout(Some(window))
            .context("arming socket read deadline")?;
        stream
            .set_write_timeout(Some(window))
            .context("arming socket write deadline")?;
        Ok(())
    }
}

/// Arm a transport socket: read/write deadlines ([`IO_TIMEOUT`]) plus
/// `TCP_NODELAY`. Timeout failures are **errors**, not advisories — a
/// socket that cannot get a deadline would hang its thread forever on
/// a stalled peer, so callers must close it instead of serving it
/// unbounded (an earlier version's `.ok()` silently did the latter).
pub fn prepare_stream(stream: &TcpStream) -> Result<()> {
    stream
        .set_read_timeout(Some(IO_TIMEOUT))
        .context("arming socket read deadline")?;
    stream
        .set_write_timeout(Some(IO_TIMEOUT))
        .context("arming socket write deadline")?;
    // Nagle costs only latency; failing to disable it is harmless.
    stream.set_nodelay(true).ok();
    Ok(())
}

/// An HTTP request (client side builds one, server side parses one).
#[derive(Debug, Clone)]
pub struct Request {
    /// Request method (`GET`, `PUT`, ...), uppercase.
    pub method: String,
    /// Request target: path plus optional `?query`.
    pub target: String,
    /// Additional headers, lowercase names. `content-length` and
    /// `connection` are managed by the codec.
    pub headers: Vec<(String, String)>,
    /// Request body.
    pub body: Vec<u8>,
}

impl Request {
    /// Build a body-less request.
    pub fn new(method: &str, target: &str) -> Request {
        Request {
            method: method.to_string(),
            target: target.to_string(),
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    /// Attach a header (builder style).
    pub fn header(mut self, name: &str, value: &str) -> Request {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }

    /// Attach a body (builder style).
    pub fn body(mut self, body: Vec<u8>) -> Request {
        self.body = body;
        self
    }

    /// Case-insensitive header lookup.
    pub fn get_header(&self, name: &str) -> Option<&str> {
        header_value(&self.headers, name)
    }

    /// The target's path component (before any `?`).
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// The target's query string (after `?`), if any.
    pub fn query(&self) -> Option<&str> {
        self.target.split_once('?').map(|(_, q)| q)
    }

    /// The declared body length (`0` when absent, error when invalid
    /// or over the transport limit). Used by streaming consumers that
    /// read the head first and the body separately.
    pub fn declared_len(&self) -> Result<u64> {
        content_length(&self.headers)
    }

    /// Whether the peer asked to close the connection after this
    /// exchange (HTTP/1.1 defaults to keep-alive).
    pub fn wants_close(&self) -> bool {
        self.get_header("connection")
            .map_or(false, |v| v.eq_ignore_ascii_case("close"))
    }
}

/// An HTTP response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// Headers, lowercase names (`content-length` is codec-managed).
    pub headers: Vec<(String, String)>,
    /// Response body — possibly truncated; check [`Response::complete`].
    pub body: Vec<u8>,
    /// Whether the body arrived complete per its `Content-Length`.
    /// `false` means the connection died mid-body; `body` holds the
    /// prefix that made it through (resume fodder).
    pub complete: bool,
}

impl Response {
    /// Build an empty response with a status code.
    pub fn new(status: u16) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: Vec::new(),
            complete: true,
        }
    }

    /// Attach a header (builder style).
    pub fn header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }

    /// Attach a body (builder style).
    pub fn body(mut self, body: Vec<u8>) -> Response {
        self.body = body;
        self
    }

    /// Case-insensitive header lookup.
    pub fn get_header(&self, name: &str) -> Option<&str> {
        header_value(&self.headers, name)
    }
}

/// A response whose body was streamed into a caller-provided sink
/// instead of buffered (see [`HttpClient::fetch_to_sink`]).
#[derive(Debug)]
pub struct SinkResponse {
    /// Status code.
    pub status: u16,
    /// Headers, lowercase names.
    pub headers: Vec<(String, String)>,
    /// Body bytes written to the sink (streamed statuses only).
    pub streamed: u64,
    /// Whether the full declared body arrived. `false` means the
    /// connection died mid-body; the sink holds the prefix.
    pub complete: bool,
    /// Buffered body for statuses the caller did *not* ask to stream
    /// (error reporting); empty for streamed statuses.
    pub body: Vec<u8>,
}

impl SinkResponse {
    /// Case-insensitive header lookup.
    pub fn get_header(&self, name: &str) -> Option<&str> {
        header_value(&self.headers, name)
    }
}

/// Case-insensitive header lookup over a parsed header list.
pub fn header_value<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

/// Extract `host:port` from an `http://` URL (port defaults to 80).
pub fn authority_of(url: &str) -> Result<String> {
    let rest = url
        .strip_prefix("http://")
        .with_context(|| format!("'{url}' is not an http:// URL"))?;
    let authority = rest.split('/').next().unwrap_or(rest);
    if authority.is_empty() {
        bail!("'{url}' has no host");
    }
    if authority.contains(':') {
        Ok(authority.to_string())
    } else {
        Ok(format!("{authority}:80"))
    }
}

/// Reject `http://` URLs carrying a path component. The git-theta wire
/// protocol is rooted at `/`; a path would be silently dropped and the
/// request would land on the wrong (root) remote.
pub fn require_rootless(url: &str) -> Result<()> {
    let rest = url.strip_prefix("http://").unwrap_or(url);
    if let Some((_, path)) = rest.split_once('/') {
        if !path.trim_end_matches('/').is_empty() {
            bail!(
                "'{url}' has a path component; git-theta http remotes are served at the \
                 server root (use http://host:port)"
            );
        }
    }
    Ok(())
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read a stream until the blank line ending the head. Returns the head
/// text and any body bytes that arrived in the same reads. With a
/// `deadline`, the socket timeout is re-armed to the remaining budget
/// before every read, so a slow-loris head is cut at the deadline.
fn read_head(stream: &mut TcpStream, deadline: Option<&Deadline>) -> Result<(String, Vec<u8>)> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(pos) = find_head_end(&buf) {
            let head = String::from_utf8(buf[..pos].to_vec()).context("non-utf8 http head")?;
            return Ok((head, buf[pos + 4..].to_vec()));
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("http head exceeds {MAX_HEAD_BYTES} bytes");
        }
        if let Some(d) = deadline {
            d.arm(stream)?;
        }
        let n = stream.read(&mut chunk).context("reading http head")?;
        if n == 0 {
            // Typed as an io error so the retry layer can classify a
            // peer that vanished between requests as retryable.
            return Err(anyhow::Error::new(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before the http head completed",
            )));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// Read up to `len` body bytes, starting from `leftover`. Returns the
/// bytes and whether the full declared length arrived. IO errors and
/// early EOF mid-body are reported as an incomplete body, not an error,
/// so callers can persist the prefix for a later resume.
pub fn read_body(stream: &mut TcpStream, leftover: Vec<u8>, len: u64) -> (Vec<u8>, bool) {
    let mut body = leftover;
    if body.len() as u64 > len {
        body.truncate(len as usize);
    }
    let mut chunk = [0u8; COPY_CHUNK];
    while (body.len() as u64) < len {
        match stream.read(&mut chunk) {
            Ok(0) => return (body, false),
            Ok(n) => {
                let want = (len - body.len() as u64) as usize;
                body.extend_from_slice(&chunk[..n.min(want)]);
            }
            Err(_) => return (body, false),
        }
    }
    (body, true)
}

/// Stream up to `len` body bytes into `sink`, starting from `leftover`.
///
/// Returns `(bytes written, complete)`. Socket read errors and early
/// EOF read as an incomplete body (the sink holds the prefix that
/// arrived — resume fodder); **sink write errors are real errors** (a
/// full disk must not masquerade as a network cut). Peak memory is one
/// [`COPY_CHUNK`], whatever `len` is — this is the receive half of the
/// streaming pack pipeline.
pub fn read_body_to<W: Write>(
    stream: &mut TcpStream,
    leftover: &[u8],
    len: u64,
    sink: &mut W,
) -> Result<(u64, bool)> {
    read_body_to_within(stream, leftover, len, sink, None)
}

/// [`read_body_to`] under a per-request [`Deadline`]: the socket
/// timeout is re-armed to the remaining budget before every chunk, so
/// a slow-dripping peer is cut when the budget runs out. Expiry
/// surfaces as an incomplete body whose prefix is already in `sink` —
/// exactly like a peer that died, so resume persistence still works.
pub fn read_body_to_within<W: Write>(
    stream: &mut TcpStream,
    leftover: &[u8],
    len: u64,
    sink: &mut W,
    deadline: Option<&Deadline>,
) -> Result<(u64, bool)> {
    let head = (leftover.len() as u64).min(len) as usize;
    sink.write_all(&leftover[..head]).context("writing streamed body")?;
    let mut written = head as u64;
    let mut chunk = [0u8; COPY_CHUNK];
    while written < len {
        if let Some(d) = deadline {
            if d.arm(stream).is_err() {
                return Ok((written, false));
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok((written, false)),
            Ok(n) => {
                let want = ((len - written) as usize).min(n);
                sink.write_all(&chunk[..want]).context("writing streamed body")?;
                written += want as u64;
            }
            Err(_) => return Ok((written, false)),
        }
    }
    Ok((written, true))
}

fn parse_headers(lines: &mut std::str::Lines<'_>) -> Vec<(String, String)> {
    let mut headers = Vec::new();
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    headers
}

fn content_length(headers: &[(String, String)]) -> Result<u64> {
    let len = match header_value(headers, "content-length") {
        Some(v) => v.parse::<u64>().context("invalid content-length")?,
        None => 0,
    };
    if len > MAX_BODY_BYTES {
        bail!("declared body of {len} bytes exceeds the transport limit");
    }
    Ok(len)
}

/// Parse a request *head* from a stream: the returned [`Request`] has
/// an empty body; the second value is any body bytes that arrived in
/// the same reads (pass them to [`read_body`] / [`read_body_to`]).
///
/// This is the server's streaming entry point: routes that spill large
/// bodies to disk read the head first and drain the body themselves.
pub fn read_request_head(stream: &mut TcpStream) -> Result<(Request, Vec<u8>)> {
    read_request_head_within(stream, None)
}

/// [`read_request_head`] under a per-request [`Deadline`] (re-armed
/// before every read), so a peer drizzling header bytes cannot hold a
/// server worker past its request budget.
pub fn read_request_head_within(
    stream: &mut TcpStream,
    deadline: Option<&Deadline>,
) -> Result<(Request, Vec<u8>)> {
    let (head, leftover) = read_head(stream, deadline)?;
    let mut lines = head.lines();
    let start = lines.next().context("empty http request")?;
    let mut parts = start.split_whitespace();
    let method = parts.next().context("missing method")?.to_ascii_uppercase();
    let target = parts.next().context("missing request target")?.to_string();
    let headers = parse_headers(&mut lines);
    Ok((
        Request {
            method,
            target,
            headers,
            body: Vec::new(),
        },
        leftover,
    ))
}

/// Parse one request from a stream, buffering the body. The `bool` is
/// body completeness — `false` means the connection died mid-body (the
/// request carries the prefix that arrived).
pub fn read_request(stream: &mut TcpStream) -> Result<(Request, bool)> {
    let (mut req, leftover) = read_request_head(stream)?;
    let len = req.declared_len()?;
    let (body, complete) = read_body(stream, leftover, len);
    req.body = body;
    Ok((req, complete))
}

/// Write a request head declaring `content_length` body bytes (which
/// the caller then sends separately — streaming uploads and the fault
/// proxy use the split).
pub fn write_request_head(
    stream: &mut TcpStream,
    method: &str,
    target: &str,
    headers: &[(String, String)],
    content_length: u64,
) -> Result<()> {
    let mut head = format!("{method} {target} HTTP/1.1\r\n");
    push_headers(&mut head, headers);
    head.push_str(&format!("content-length: {content_length}\r\n\r\n"));
    stream
        .write_all(head.as_bytes())
        .context("writing http request head")
}

/// Append caller headers, skipping the codec-managed ones so relaying
/// a parsed message (the fault proxy does) never duplicates them.
fn push_headers(head: &mut String, headers: &[(String, String)]) {
    for (name, value) in headers {
        if name.eq_ignore_ascii_case("content-length") || name.eq_ignore_ascii_case("connection") {
            continue;
        }
        head.push_str(&format!("{name}: {value}\r\n"));
    }
}

/// Write a complete request.
pub fn write_request(stream: &mut TcpStream, req: &Request) -> Result<()> {
    write_request_head(
        stream,
        &req.method,
        &req.target,
        &req.headers,
        req.body.len() as u64,
    )?;
    stream
        .write_all(&req.body)
        .context("writing http request body")?;
    stream.flush().context("flushing http request")
}

fn reason_of(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        204 => "No Content",
        206 => "Partial Content",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        416 => "Range Not Satisfiable",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// Write a response head declaring `content_length` body bytes.
pub fn write_response_head(
    stream: &mut TcpStream,
    status: u16,
    headers: &[(String, String)],
    content_length: u64,
) -> Result<()> {
    let mut head = format!("HTTP/1.1 {status} {}\r\n", reason_of(status));
    push_headers(&mut head, headers);
    head.push_str(&format!("content-length: {content_length}\r\n\r\n"));
    stream
        .write_all(head.as_bytes())
        .context("writing http response head")
}

/// Write a complete response.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    write_response_head(stream, resp.status, &resp.headers, resp.body.len() as u64)?;
    stream
        .write_all(&resp.body)
        .context("writing http response body")?;
    stream.flush().context("flushing http response")
}

/// Parse a response *head*: status, headers, and any body bytes that
/// arrived in the same reads.
fn read_response_head(stream: &mut TcpStream) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let (head, leftover) = read_head(stream, None)?;
    let mut lines = head.lines();
    let start = lines.next().context("empty http response")?;
    let status = start
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .with_context(|| format!("bad http status line '{start}'"))?;
    let headers = parse_headers(&mut lines);
    Ok((status, headers, leftover))
}

/// Parse one response from a stream. `head_request` suppresses body
/// reading (HEAD responses declare a length but carry no body).
pub fn read_response(stream: &mut TcpStream, head_request: bool) -> Result<Response> {
    let (status, headers, leftover) = read_response_head(stream)?;
    if head_request {
        return Ok(Response {
            status,
            headers,
            body: Vec::new(),
            complete: true,
        });
    }
    let len = content_length(&headers)?;
    let (body, complete) = read_body(stream, leftover, len);
    Ok(Response {
        status,
        headers,
        body,
        complete,
    })
}

fn fresh_connection(authority: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(authority)
        .with_context(|| format!("connecting to http remote {authority}"))?;
    prepare_stream(&stream).with_context(|| format!("configuring socket to {authority}"))?;
    Ok(stream)
}

/// Connect, send one request, read the response, drop the connection.
///
/// The unpooled one-shot path, kept for tests and the fault proxy;
/// production clients go through [`HttpClient`] so consecutive
/// requests reuse one connection.
pub fn roundtrip(authority: &str, req: &Request) -> Result<Response> {
    let mut stream = fresh_connection(authority)?;
    write_request(&mut stream, req)?;
    read_response(&mut stream, req.method == "HEAD")
}

/// Shared HTTP client scaffold: endpoint parsing, a keep-alive
/// connection pool, and complete-response enforcement.
///
/// `lfs/http.rs` (pack transport) and `gitcore/remote.rs` (commit/ref
/// endpoint) used to each carry their own copy of this plumbing and
/// opened one TCP connection per request; they now share one scaffold,
/// and a multi-request push or fetch runs over a single persistent
/// connection. Pooling rules:
///
/// * A connection returns to the pool only after a *complete* response
///   — a stream that died mid-body is dropped.
/// * **Reconnect-on-stale**: a pooled connection may have been closed
///   by an idle timeout or server restart. If the first use of a
///   *reused* connection fails before a response head arrives, the
///   request is retried once on a fresh connection — but only for
///   read-style methods (`GET`/`HEAD`/`POST` queries); `PUT`s are
///   never silently re-sent, because a resumable pack upload that
///   half-arrived must surface to its caller's offset logic instead.
/// * [`HttpClient::connections_opened`] counts real TCP connects, so
///   tests and the transfer ablation can assert N requests ≤ a handful
///   of connects.
#[derive(Debug)]
pub struct HttpClient {
    authority: String,
    url: String,
    /// Idle connections with the instant they were checked in.
    pool: Mutex<Vec<(TcpStream, std::time::Instant)>>,
    opened: AtomicU64,
}

impl HttpClient {
    /// Parse an `http://host:port` endpoint (no path component; see
    /// [`require_rootless`]). No connection is made until first use.
    pub fn open(url: &str) -> Result<HttpClient> {
        require_rootless(url)?;
        Ok(HttpClient {
            authority: authority_of(url)?,
            url: url.trim_end_matches('/').to_string(),
            pool: Mutex::new(Vec::new()),
            opened: AtomicU64::new(0),
        })
    }

    /// The endpoint URL this client talks to.
    pub fn url(&self) -> &str {
        &self.url
    }

    /// How many TCP connections this client has opened since creation.
    /// With keep-alive working, this stays far below the request count.
    pub fn connections_opened(&self) -> u64 {
        self.opened.load(Ordering::Relaxed)
    }

    /// Take a pooled connection (true = reused) or dial a fresh one.
    /// Pooled connections idle past [`POOL_IDLE_MAX`] are discarded —
    /// the peer's idle timeout has probably closed them, and a `PUT`
    /// handed a dead socket cannot be silently re-sent.
    fn checkout(&self) -> Result<(TcpStream, bool)> {
        {
            let mut pool = self.pool.lock().unwrap();
            while let Some((stream, since)) = pool.pop() {
                if since.elapsed() < POOL_IDLE_MAX {
                    return Ok((stream, true));
                }
                // too old: drop and keep looking
            }
        }
        let stream = fresh_connection(&self.authority)?;
        self.opened.fetch_add(1, Ordering::Relaxed);
        Ok((stream, false))
    }

    /// Return a healthy connection to the pool.
    fn checkin(&self, stream: TcpStream) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push((stream, std::time::Instant::now()));
        }
    }

    fn may_retry_stale(method: &str) -> bool {
        matches!(method, "GET" | "HEAD" | "POST")
    }

    /// The one copy of the stale-retry policy: write `req` over a
    /// pooled connection, run `exchange` to read (at least) the
    /// response head, and — iff the connection was a *reused* one that
    /// failed before `exchange` succeeded, and the method is
    /// retry-safe — clear the pool (its other members are just as
    /// likely dead) and retry once on a fresh dial. Returns the live
    /// stream so the caller can drain the body and decide on checkin.
    fn with_connection<T>(
        &self,
        req: &Request,
        mut exchange: impl FnMut(&mut TcpStream) -> Result<T>,
    ) -> Result<(TcpStream, T)> {
        let retryable = Self::may_retry_stale(&req.method);
        for attempt in 0..2 {
            let (mut stream, reused) = self.checkout()?;
            match write_request(&mut stream, req).and_then(|_| exchange(&mut stream)) {
                Ok(v) => return Ok((stream, v)),
                Err(_) if reused && retryable && attempt == 0 => {
                    self.pool.lock().unwrap().clear();
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!("stale-retry loop always returns on the fresh attempt");
    }

    /// Send a buffered request over a pooled connection and read the
    /// (possibly incomplete) response.
    pub fn roundtrip(&self, req: &Request) -> Result<Response> {
        let (stream, resp) =
            self.with_connection(req, |s| read_response(s, req.method == "HEAD"))?;
        if resp.complete {
            self.checkin(stream);
        }
        Ok(resp)
    }

    /// [`HttpClient::roundtrip`] + require a complete response body.
    pub fn send(&self, req: &Request) -> Result<Response> {
        let resp = self.roundtrip(req)?;
        if !resp.complete {
            // Typed as an io error so the retry layer classifies a
            // connection that died mid-response as retryable.
            return Err(anyhow::Error::new(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("connection to {} interrupted mid-response", self.url),
            )));
        }
        Ok(resp)
    }

    /// Stream `body_len` bytes of `file` starting at `offset` as the
    /// body of a request, in [`COPY_CHUNK`] pieces — the send half of
    /// the streaming pack pipeline (peak memory is one chunk, whatever
    /// the pack size). Never stale-retried: a partially delivered
    /// upload must surface to the caller's resume-offset logic.
    pub fn send_file(
        &self,
        method: &str,
        target: &str,
        headers: &[(String, String)],
        file: &mut std::fs::File,
        offset: u64,
        body_len: u64,
    ) -> Result<Response> {
        file.seek(SeekFrom::Start(offset)).context("seeking pack file")?;
        let (mut stream, _reused) = self.checkout()?;
        write_request_head(&mut stream, method, target, headers, body_len)?;
        let mut sent = 0u64;
        let mut chunk = vec![0u8; COPY_CHUNK];
        while sent < body_len {
            let want = ((body_len - sent) as usize).min(chunk.len());
            file.read_exact(&mut chunk[..want])
                .context("reading pack file for upload")?;
            stream
                .write_all(&chunk[..want])
                .context("writing streamed request body")?;
            sent += want as u64;
        }
        stream.flush().context("flushing streamed request")?;
        let resp = read_response(&mut stream, method == "HEAD")?;
        // Only a 200 proves the server drained our whole body; on an
        // early error response (409 offset conflict, 400) it closes
        // the connection instead, so pooling it would hand the next
        // request a dead socket.
        if resp.complete && resp.status == 200 {
            self.checkin(stream);
        }
        Ok(resp)
    }

    /// Send a request and stream the response body into `sink` when
    /// the status is in `stream_statuses`; other statuses buffer their
    /// (small) body for error reporting — a 404 must not pollute a
    /// partial-pack file. Incomplete bodies are reported via
    /// [`SinkResponse::complete`], with the received prefix already in
    /// the sink.
    pub fn fetch_to_sink<W: Write>(
        &self,
        req: &Request,
        stream_statuses: &[u16],
        sink: &mut W,
    ) -> Result<SinkResponse> {
        // Only the head read sits inside the retry window: once it
        // arrives, body bytes may touch the sink and a silent re-send
        // would be unsound, so the body is drained out here.
        let (mut stream, (status, headers, leftover)) =
            self.with_connection(req, read_response_head)?;
        let len = content_length(&headers)?;
        if !stream_statuses.contains(&status) {
            let (body, complete) = read_body(&mut stream, leftover, len);
            if complete {
                self.checkin(stream);
            }
            return Ok(SinkResponse {
                status,
                headers,
                streamed: 0,
                complete,
                body,
            });
        }
        let (streamed, complete) = read_body_to(&mut stream, &leftover, len, sink)?;
        if complete {
            self.checkin(stream);
        }
        Ok(SinkResponse {
            status,
            headers,
            streamed,
            complete,
            body: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn authority_parsing() {
        assert_eq!(authority_of("http://127.0.0.1:8123").unwrap(), "127.0.0.1:8123");
        assert_eq!(authority_of("http://host:9/x/y").unwrap(), "host:9");
        assert_eq!(authority_of("http://host").unwrap(), "host:80");
        assert!(authority_of("file:///tmp").is_err());
        assert!(authority_of("http://").is_err());
        assert!(require_rootless("http://host:9").is_ok());
        assert!(require_rootless("http://host:9/").is_ok());
        assert!(require_rootless("http://host:9/team-a").is_err());
    }

    #[test]
    fn request_target_split() {
        let req = Request::new("GET", "/history/abc?exclude=1,2");
        assert_eq!(req.path(), "/history/abc");
        assert_eq!(req.query(), Some("exclude=1,2"));
        assert_eq!(Request::new("GET", "/x").query(), None);
    }

    /// A tiny keep-alive echo server: answers every request on a
    /// connection until the peer closes.
    fn spawn_echo() -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let mut stream = match conn {
                    Ok(s) => s,
                    Err(_) => break,
                };
                std::thread::spawn(move || loop {
                    let (req, complete) = match read_request(&mut stream) {
                        Ok(v) => v,
                        Err(_) => return,
                    };
                    if !complete {
                        return;
                    }
                    let resp = Response::new(200).header("x-seen", "yes").body(req.body);
                    if write_response(&mut stream, &resp).is_err() {
                        return;
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn roundtrip_over_real_socket() {
        let addr = spawn_echo();
        let payload: Vec<u8> = (0..100_000u32).map(|x| x as u8).collect();
        let req = Request::new("PUT", "/echo").header("x-tag", "t1").body(payload.clone());
        let resp = roundtrip(&addr.to_string(), &req).unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.complete);
        assert_eq!(resp.get_header("x-seen"), Some("yes"));
        assert_eq!(resp.body, payload);
    }

    #[test]
    fn pooled_client_reuses_one_connection() {
        let addr = spawn_echo();
        let client = HttpClient::open(&format!("http://{addr}")).unwrap();
        for i in 0..5 {
            let req = Request::new("POST", "/echo").body(vec![i as u8; 100]);
            let resp = client.send(&req).unwrap();
            assert_eq!(resp.body, vec![i as u8; 100]);
        }
        assert_eq!(
            client.connections_opened(),
            1,
            "five sequential requests must share one connection"
        );
    }

    #[test]
    fn stale_pooled_connection_reconnects() {
        // A server that closes every connection after one response:
        // each pooled reuse is stale, and the client must transparently
        // reconnect for GET-style requests.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let mut stream = match conn {
                    Ok(s) => s,
                    Err(_) => break,
                };
                if let Ok((_req, true)) = read_request(&mut stream) {
                    let _ = write_response(&mut stream, &Response::new(200).body(b"ok".to_vec()));
                }
                // drop → connection closed
            }
        });
        let client = HttpClient::open(&format!("http://{addr}")).unwrap();
        for _ in 0..3 {
            let resp = client.send(&Request::new("GET", "/x")).unwrap();
            assert_eq!(resp.body, b"ok");
        }
        assert_eq!(client.connections_opened(), 3, "every reuse was stale");
    }

    #[test]
    fn restart_surfaces_puts_but_transparently_retries_reads() {
        // A "restarting" server: every connection answers exactly one
        // request, then closes — so a pooled connection is always
        // stale by its next use. This pins the `may_retry_stale`
        // policy: read-style methods reconnect transparently, while a
        // PUT handed a dead socket must surface the failure to its
        // caller's resume-offset logic instead of being silently
        // re-sent.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let mut stream = match conn {
                    Ok(s) => s,
                    Err(_) => break,
                };
                if let Ok((_req, true)) = read_request(&mut stream) {
                    let _ = write_response(&mut stream, &Response::new(200).body(b"ok".to_vec()));
                }
            }
        });
        let client = HttpClient::open(&format!("http://{addr}")).unwrap();
        assert_eq!(client.send(&Request::new("GET", "/x")).unwrap().status, 200);
        assert_eq!(client.connections_opened(), 1);
        // Give the server's close a moment to land on the pooled socket.
        std::thread::sleep(Duration::from_millis(50));
        client
            .send(&Request::new("PUT", "/y").body(vec![1u8; 64]))
            .expect_err("a PUT over a dead pooled connection must surface, not re-send");
        assert_eq!(
            client.connections_opened(),
            1,
            "the failed PUT must not have been silently re-sent on a fresh dial"
        );
        // Reads recover on their own: a fresh dial behind the scenes.
        assert_eq!(client.send(&Request::new("GET", "/z")).unwrap().body, b"ok");
        assert_eq!(client.connections_opened(), 2);
    }

    #[test]
    fn deadline_cuts_a_slow_loris_body() {
        // A client that declares 1000 body bytes, drips a few, then
        // stalls while holding the socket open. The server-side read
        // under a ~300 ms deadline must cut within the budget (not the
        // 30 s socket timeout), keeping the received prefix.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            write_request_head(&mut stream, "PUT", "/drip", &[], 1000).unwrap();
            for _ in 0..5 {
                let _ = stream.write_all(&[7u8]);
                let _ = stream.flush();
                std::thread::sleep(Duration::from_millis(20));
            }
            // Stall, holding the connection open past the deadline.
            std::thread::sleep(Duration::from_millis(1500));
        });
        let (mut stream, _) = listener.accept().unwrap();
        prepare_stream(&stream).unwrap();
        let (req, leftover) = read_request_head(&mut stream).unwrap();
        assert_eq!(req.declared_len().unwrap(), 1000);
        let deadline = Deadline::after(Duration::from_millis(300));
        let started = std::time::Instant::now();
        let mut sink = Vec::new();
        let (written, complete) =
            read_body_to_within(&mut stream, &leftover, 1000, &mut sink, Some(&deadline)).unwrap();
        assert!(!complete, "a stalled body must read as incomplete");
        assert!(written < 1000);
        assert_eq!(sink.len() as u64, written);
        assert!(deadline.expired());
        assert!(
            started.elapsed() < Duration::from_millis(1400),
            "the deadline, not the peer, must end the read"
        );
        client.join().unwrap();
    }

    #[test]
    fn send_file_streams_a_region() {
        let addr = spawn_echo();
        let client = HttpClient::open(&format!("http://{addr}")).unwrap();
        let td = crate::util::tmp::TempDir::new("httpfile").unwrap();
        let path = td.join("body.bin");
        let payload: Vec<u8> = (0..200_000u32).map(|x| (x % 251) as u8).collect();
        std::fs::write(&path, &payload).unwrap();
        let mut f = std::fs::File::open(&path).unwrap();
        let resp = client
            .send_file("PUT", "/echo", &[], &mut f, 1000, payload.len() as u64 - 1000)
            .unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, &payload[1000..]);
    }

    #[test]
    fn fetch_to_sink_streams_only_expected_statuses() {
        let addr = spawn_echo();
        let client = HttpClient::open(&format!("http://{addr}")).unwrap();
        let payload = vec![9u8; 50_000];
        let mut sink = Vec::new();
        let resp = client
            .fetch_to_sink(
                &Request::new("POST", "/echo").body(payload.clone()),
                &[200],
                &mut sink,
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.complete);
        assert_eq!(resp.streamed, payload.len() as u64);
        assert_eq!(sink, payload);
        assert!(resp.body.is_empty());
    }

    #[test]
    fn truncated_body_is_reported_incomplete() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // Declare 1000 body bytes but send only 400, then drop.
            write_response_head(&mut stream, 200, &[], 1000).unwrap();
            stream.write_all(&[7u8; 400]).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        write_request(&mut stream, &Request::new("GET", "/partial")).unwrap();
        let resp = read_response(&mut stream, false).unwrap();
        server.join().unwrap();
        assert_eq!(resp.status, 200);
        assert!(!resp.complete);
        assert_eq!(resp.body, vec![7u8; 400]);
    }

    #[test]
    fn truncated_body_into_sink_keeps_prefix() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            write_response_head(&mut stream, 200, &[], 1000).unwrap();
            stream.write_all(&[7u8; 400]).unwrap();
        });
        let client = HttpClient::open(&format!("http://{addr}")).unwrap();
        let mut sink = Vec::new();
        let resp = client
            .fetch_to_sink(&Request::new("GET", "/partial"), &[200], &mut sink)
            .unwrap();
        server.join().unwrap();
        assert!(!resp.complete);
        assert_eq!(resp.streamed, 400);
        assert_eq!(sink, vec![7u8; 400]);
    }
}
