//! Replication suite: the robustness proof for multi-mirror remotes.
//!
//! Two deterministic phases drive a [`ReplicatedRemote`] through the
//! failure shapes it exists for and lock the outcome in
//! `BENCH_replicate.json`:
//!
//! 1. **Quorum-degraded push + anti-entropy repair** — a three-mirror
//!    set (two live directory mirrors, one dead HTTP mirror) takes a
//!    push at write quorum 2. The push must succeed, register a
//!    quorum shortfall, and leave the dead mirror behind; then the
//!    mirror comes back empty, `repair` ships it exactly the missing
//!    objects, and all three stores must end byte-identical — a
//!    second repair must find nothing to do.
//! 2. **Mid-pack mirror death + failover resume** — two identically
//!    seeded HTTP mirrors serve a fetch; a [`FaultProxy`] kills the
//!    first mirror's pack stream at byte `k`. One `fetch_pack` call
//!    must complete by failing over to the second mirror, resuming
//!    from the dead mirror's `k`-byte partial (shared staging), so
//!    exactly `pack − k` bytes cross the wire on the survivor.
//!
//! Zero checksum failures are admitted in either phase. The run is
//! seeded; a failing run replays with
//! `git-theta bench replicate <objects> <seed>`.

use super::write_bench_json;
use crate::gitcore::object::Oid;
use crate::lfs::faults::{Direction, FaultProxy, FaultSpec};
use crate::lfs::{batch, DirRemote, HttpRemote, LfsServer, LfsStore, ReplicatedRemote};
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use anyhow::{ensure, Context, Result};
use std::time::Instant;

/// Replication-suite shape. Equal configs replay the same payloads.
#[derive(Debug, Clone, Copy)]
pub struct ReplicateConfig {
    /// Objects pushed/fetched per phase.
    pub objects: usize,
    /// Master seed for payloads.
    pub seed: u64,
}

/// Replication verdict: the convergence bit plus the counters the
/// baseline locks.
#[derive(Debug, Clone, Copy)]
pub struct ReplicateOutcome {
    /// Objects per phase.
    pub objects: usize,
    /// Every mirror store ended byte-identical in both phases.
    pub converged: bool,
    /// Pushes that met quorum but left a mirror behind (phase 1).
    pub quorum_shortfalls: u64,
    /// Objects the anti-entropy repair shipped to the laggard.
    pub repair_objects: u64,
    /// Fetches that abandoned a dying mirror mid-pack (phase 2).
    pub failovers: u64,
    /// Bytes the failover skipped by resuming the dead mirror's
    /// partial (phase 2; must equal the kill offset).
    pub resumed_bytes: u64,
    /// Byte mismatches found across all convergence checks — locked
    /// to exactly zero.
    pub checksum_failures: u64,
    /// Wall-clock seconds for the whole run.
    pub replicate_secs: f64,
}

/// Deterministic ~2 KiB payload for `(seed, object)`.
fn payload(seed: u64, object: usize) -> Vec<u8> {
    let mut rng = Pcg64::new(seed ^ (object as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..2048).map(|_| rng.next_u32() as u8).collect()
}

/// Count objects in `stores` whose bytes differ from `local`'s.
fn divergent_objects(local: &LfsStore, stores: &[LfsStore], oids: &[Oid]) -> Result<u64> {
    let mut failures = 0u64;
    for oid in oids {
        let want = local.get(oid)?;
        for (i, store) in stores.iter().enumerate() {
            if !matches!(store.get(oid), Ok(ref b) if *b == want) {
                eprintln!("replicate DIVERGED: mirror {i} lost or corrupted object {oid}");
                failures += 1;
            }
        }
    }
    Ok(failures)
}

/// Phase 1: push at quorum 2-of-3 with one mirror dead, then revive it
/// and prove anti-entropy repair converges all three stores.
fn quorum_phase(cfg: &ReplicateConfig) -> Result<(u64, u64, u64)> {
    let td = TempDir::new("bench-replicate-quorum")?;
    let local = LfsStore::open(&td.join("local"));
    let oids: Vec<Oid> = (0..cfg.objects)
        .map(|i| local.put(&payload(cfg.seed, i)).map(|(o, _)| o))
        .collect::<Result<_>>()?;

    let (root_a, root_b, root_c) = (td.join("mirror-a"), td.join("mirror-b"), td.join("mirror-c"));
    for root in [&root_a, &root_b, &root_c] {
        std::fs::create_dir_all(root)?;
    }
    // Reserve an address for the third mirror, then leave it dead: a
    // connect to it fails until the revival below binds the same port.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").context("reserving mirror c")?;
    let addr = listener.local_addr()?;
    drop(listener);

    let replica = ReplicatedRemote::new(
        vec![
            Box::new(DirRemote::open(&root_a)),
            Box::new(DirRemote::open(&root_b)),
            Box::new(HttpRemote::open(&format!("http://{addr}"), Some(&td.join("staging")))?),
        ],
        Some(2),
    );

    batch::reset_stats();
    let pushed = batch::push_pack(&local, &replica, &oids).context("quorum-degraded push")?;
    ensure!(pushed.unavailable == 0, "quorum push left objects behind");
    let shortfalls = batch::stats().quorum_shortfalls;
    ensure!(shortfalls >= 1, "the dead mirror never registered a quorum shortfall");

    // The dead mirror comes back empty on the same address; repair
    // negotiates have/want against the union and ships what it missed.
    let server = LfsServer::spawn_on(&root_c, &addr.to_string())?;
    let report = replica.repair(2).context("anti-entropy repair")?;
    ensure!(
        report.laggards_healed == 1,
        "expected exactly the revived mirror healed, got {}",
        report.laggards_healed
    );
    ensure!(
        report.objects_shipped == oids.len() as u64,
        "repair shipped {} of {} missing objects",
        report.objects_shipped,
        oids.len()
    );
    let second = replica.repair(2)?;
    ensure!(
        second.objects_shipped == 0 && second.laggards_healed == 0,
        "a second repair pass must find nothing to ship"
    );

    let stores = [
        LfsStore::at(&root_a.join("lfs/objects")),
        LfsStore::at(&root_b.join("lfs/objects")),
        LfsStore::at(&root_c.join("lfs/objects")),
    ];
    let failures = divergent_objects(&local, &stores, &oids)?;
    server.shutdown();
    Ok((shortfalls, report.objects_shipped, failures))
}

/// Phase 2: kill mirror A's pack stream at byte `k` mid-fetch; one
/// call must fail over and resume from the partial on mirror B.
fn failover_phase(cfg: &ReplicateConfig) -> Result<(u64, u64, u64)> {
    let td = TempDir::new("bench-replicate-failover")?;
    let (root_a, root_b) = (td.join("server-a"), td.join("server-b"));
    for root in [&root_a, &root_b] {
        std::fs::create_dir_all(root)?;
    }
    let store_a = LfsStore::at(&root_a.join("lfs/objects"));
    let store_b = LfsStore::at(&root_b.join("lfs/objects"));
    let mut oids = Vec::with_capacity(cfg.objects);
    for i in 0..cfg.objects {
        let bytes = payload(cfg.seed ^ 0xF0F0, i);
        oids.push(store_a.put(&bytes)?.0);
        store_b.put(&bytes)?;
    }
    let server_a = LfsServer::spawn(&root_a)?;
    let server_b = LfsServer::spawn(&root_b)?;
    let proxy = FaultProxy::spawn(&server_a.url())?;

    // Learn the pack size with an unfaulted fetch into a scratch store.
    let scratch_root = td.join("scratch");
    let scratch = LfsStore::open(&scratch_root);
    let direct = HttpRemote::open(&server_b.url(), Some(&scratch_root))?;
    let pack_bytes = batch::fetch_pack(&direct, &scratch, &oids)?.packed_bytes;
    ensure!(pack_bytes > 2, "fixture pack too small to cut");
    let k = pack_bytes / 2;

    // Both mirrors share the fetching repo's staging dir, so the
    // partial the dying mirror leaves is the prefix the survivor
    // resumes (packs are content-addressed: same want set, same id).
    let local_root = td.join("local");
    let local = LfsStore::open(&local_root);
    let replica = ReplicatedRemote::new(
        vec![
            Box::new(HttpRemote::open(&proxy.url(), Some(&local_root))?),
            Box::new(HttpRemote::open(&server_b.url(), Some(&local_root))?),
        ],
        None,
    );
    proxy.arm(FaultSpec::kill(Direction::Download, k));
    batch::reset_stats();
    let summary = batch::fetch_pack(&replica, &local, &oids)
        .context("fetch must survive a mid-pack mirror death")?;
    let stats = batch::stats();
    ensure!(proxy.fired() == 1, "the mid-pack kill never fired");
    ensure!(
        stats.mirror_failovers == 1,
        "expected exactly one failover, saw {}",
        stats.mirror_failovers
    );
    ensure!(
        summary.resumed_bytes == k,
        "failover resumed {} bytes; the dead mirror delivered exactly {k}",
        summary.resumed_bytes
    );
    ensure!(
        summary.wire_bytes == pack_bytes - k,
        "survivor sent {} wire bytes; only the {}-byte tail after the cut may move",
        summary.wire_bytes,
        pack_bytes - k
    );

    let failures = divergent_objects(&store_a, &[local], &oids)?;
    drop(proxy);
    server_a.shutdown();
    server_b.shutdown();
    Ok((stats.mirror_failovers, summary.resumed_bytes, failures))
}

/// Run both phases. Convergence is reported, not assumed: a divergent
/// run returns `converged: false` so the caller (CLI, gate) decides.
pub fn run_replicate(cfg: &ReplicateConfig) -> Result<ReplicateOutcome> {
    crate::init();
    ensure!(cfg.objects >= 2, "replicate needs at least two objects");
    eprintln!(
        "replicate: {} objects, seed {} (replay: git-theta bench replicate {} {})",
        cfg.objects, cfg.seed, cfg.objects, cfg.seed
    );
    let t0 = Instant::now();
    let (quorum_shortfalls, repair_objects, quorum_failures) = quorum_phase(cfg)?;
    let (failovers, resumed_bytes, failover_failures) = failover_phase(cfg)?;
    let checksum_failures = quorum_failures + failover_failures;
    Ok(ReplicateOutcome {
        objects: cfg.objects,
        converged: checksum_failures == 0,
        quorum_shortfalls,
        repair_objects,
        failovers,
        resumed_bytes,
        checksum_failures,
        replicate_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Human-readable summary of a replication run.
pub fn render_replicate(out: &ReplicateOutcome) -> String {
    format!(
        "replicate: {} objects — {}\n\
         quorum: {} shortfall(s) absorbed, repair shipped {} object(s)\n\
         failover: {} mirror switch(es), {} bytes resumed from the dead mirror's partial; \
         {} checksum failure(s); {:.2}s\n",
        out.objects,
        if out.converged { "CONVERGED" } else { "DIVERGED" },
        out.quorum_shortfalls,
        out.repair_objects,
        out.failovers,
        out.resumed_bytes,
        out.checksum_failures,
        out.replicate_secs,
    )
}

/// Encode the run as the `BENCH_replicate.json` payload for the gate.
pub fn replicate_to_json(cfg: &ReplicateConfig, out: &ReplicateOutcome) -> Json {
    let mut root = JsonObj::new();
    root.insert("bench", "replicate");
    root.insert("objects", out.objects);
    root.insert("seed", cfg.seed);
    root.insert("converged", u64::from(out.converged));
    root.insert("quorum_shortfalls", out.quorum_shortfalls);
    root.insert("repair_objects", out.repair_objects);
    root.insert("failovers", out.failovers);
    root.insert("resumed_bytes", out.resumed_bytes);
    root.insert("checksum_failures", out.checksum_failures);
    root.insert("replicate_secs", Json::Num(out.replicate_secs));
    Json::Obj(root)
}

/// `git-theta bench replicate [objects] [seed]`.
pub fn run_replicate_cli(args: &[String]) -> Result<()> {
    let cfg = ReplicateConfig {
        objects: args.first().and_then(|s| s.parse().ok()).unwrap_or(8),
        seed: args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0x5EED_0F_A11),
    };
    let out = run_replicate(&cfg)?;
    print!("{}", render_replicate(&out));
    let path = write_bench_json("replicate", replicate_to_json(&cfg, &out))?;
    println!("wrote {}", path.display());
    ensure!(out.converged, "replicate seed {} did not converge", cfg.seed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payloads_are_deterministic_and_distinct() {
        assert_eq!(payload(7, 0), payload(7, 0));
        assert_ne!(payload(7, 0), payload(7, 1));
        assert_ne!(payload(7, 0), payload(8, 0));
    }

    #[test]
    fn tiny_replicate_run_converges_under_faults() {
        let cfg = ReplicateConfig { objects: 3, seed: 41 };
        let out = run_replicate(&cfg).unwrap();
        assert!(out.converged, "tiny replicate run diverged");
        assert!(out.quorum_shortfalls >= 1);
        assert_eq!(out.repair_objects, 3);
        assert_eq!(out.failovers, 1);
        assert!(out.resumed_bytes >= 1);
        assert_eq!(out.checksum_failures, 0);
    }
}
