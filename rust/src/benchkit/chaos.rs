//! Chaos suite: the overload-safety proof for the serving core and the
//! client resilience layer.
//!
//! Where the [`scenario`](super::scenario) harness measures *contention*
//! on a healthy hub, this harness attacks an **undersized** hub (two
//! workers, a two-slot accept queue, a sub-second request budget) with
//! the failure shapes the resilience layer exists for, and proves the
//! fleet still converges:
//!
//! 1. **Overload** — idle connections hog the whole worker pool and
//!    accept queue; new arrivals must be shed with `503 + Retry-After`
//!    (never queued without bound, never accepted and starved), and a
//!    [`RetryPolicy`]-wrapped client must ride the sheds to success
//!    once the hogs disappear.
//! 2. **Stall** — a request that sends half its body and goes silent
//!    must be cut by the server's request budget (`timed_out` counts
//!    it), with the received prefix persisted for byte-range resume.
//! 3. **Admission + pacing faults in live transfers** — one actor's
//!    traffic crosses a [`FaultProxy`] armed with reject-N-then-accept
//!    and a mid-upload stall; every actor pushes its objects and
//!    fetches everyone else's through the starved hub, and all stores
//!    must end byte-identical to the hub's.
//!
//! The run is seeded: backoff jitter and payloads derive from the
//! config seed, so a failing run replays with `git-theta bench chaos
//! <actors> <objects> <seed>`. Counters land in `BENCH_chaos.json` and
//! are locked by `scripts/bench_baseline.json` (floors for shed/retry/
//! timeout counts, an exact pin for converged and faults fired).

use super::write_bench_json;
use crate::gitcore::object::Oid;
use crate::lfs::faults::{Direction, FaultProxy, FaultSpec};
use crate::lfs::{batch, HttpRemote, LfsServer, LfsStore, Prefetcher, RetryPolicy, ServeOptions, WireError};
use crate::util::http::{self, Request};
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use anyhow::{anyhow, ensure, Context, Result};
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Half-declared body bytes the stalled upload of phase 2 sends before
/// going silent (the other half never arrives).
const STALL_SENT: usize = 4096;
/// Body byte offset of the mid-upload stall injected into the live
/// actor push of phase 3 (any pushed pack is comfortably larger).
const ACTOR_STALL_AT: u64 = 512;
/// Requests the fault proxy rejects with a local 503 in phase 3.
const REJECTS: u64 = 3;

/// Chaos shape. Equal configs replay the same payloads and jitter.
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    /// Concurrent transfer actors (each pushes then fetches the rest).
    pub actors: usize,
    /// Objects per actor.
    pub objects: usize,
    /// Master seed for payloads and backoff jitter.
    pub seed: u64,
}

/// Chaos verdict: the convergence bit plus the shed/timeout/retry
/// counters the baseline locks.
#[derive(Debug, Clone, Copy)]
pub struct ChaosOutcome {
    /// Actors the run drove.
    pub actors: usize,
    /// Objects per actor.
    pub objects: usize,
    /// Every actor store ended byte-identical to the verified hub.
    pub converged: bool,
    /// Connections the hub admitted.
    pub accepted: u64,
    /// Connections the hub shed with `503 + Retry-After`.
    pub rejected: u64,
    /// Requests the hub cut at the request budget.
    pub timed_out: u64,
    /// Requests the hub served.
    pub requests: u64,
    /// In-flight requests after drain — zero proves no leaked worker.
    pub in_flight_after_drain: u64,
    /// Client-side: 503 sheds absorbed by backoff.
    pub sheds: u64,
    /// Client-side: transient failures retried under backoff.
    pub backoff_retries: u64,
    /// Client-side: bytes byte-range resume skipped re-sending.
    pub resumed_bytes: u64,
    /// Faults the proxy injected (rejects + the stall), exact.
    pub faults_fired: u64,
    /// Wall-clock seconds for the whole run.
    pub chaos_secs: f64,
}

/// Deterministic payload for `(seed, actor, object)` — every actor can
/// derive every oid without talking to anyone.
fn payload(seed: u64, actor: usize, object: usize) -> Vec<u8> {
    let mut rng = Pcg64::new(
        seed ^ (actor as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (object as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9),
    );
    // ~3 KiB: bigger than the actor-stall offset, small enough that
    // the chaos run is dominated by faults, not payload.
    (0..3072).map(|_| rng.next_u32() as u8).collect()
}

/// Phase 1: hog every worker and queue slot with idle connections,
/// then prove a policy-wrapped probe is shed (503 + Retry-After) and
/// recovers once the hogs disappear.
fn overload_phase(server: &LfsServer, opts: &ServeOptions, seed: u64) -> Result<()> {
    let authority = http::authority_of(&server.url())?;
    let mut hogs = Vec::new();
    for _ in 0..(opts.workers + opts.queue + 2) {
        hogs.push(TcpStream::connect(authority.as_str()).context("connecting a hog")?);
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut hogs = Some(hogs);
    let policy = RetryPolicy {
        max_attempts: 6,
        base: Duration::from_millis(40),
        cap: Duration::from_millis(300),
        seed,
    };
    let mut attempt = 0u32;
    let resp = policy
        .run(|| {
            attempt += 1;
            if attempt > 1 {
                // The overload "ends": freed hogs EOF instantly, so
                // the retry finds workers available.
                hogs.take();
            }
            let resp = http::roundtrip(&authority, &Request::new("GET", "/metrics"))?;
            if resp.status == 503 {
                let after = resp.get_header("retry-after").and_then(|v| v.parse().ok());
                return Err(anyhow::Error::new(WireError::shed(
                    after,
                    "hub shed the metrics probe",
                )));
            }
            Ok(resp)
        })
        .context("overload phase: probe never got through")?;
    ensure!(resp.status == 200, "overload phase: probe ended on {}", resp.status);
    ensure!(attempt >= 2, "overload phase: the hogs never forced a shed");
    ensure!(
        server.metrics().rejected >= 1,
        "overload phase: a full pool shed nothing"
    );
    Ok(())
}

/// Phase 2: a raw half-sent upload goes silent; the request budget must
/// cut it (`timed_out`) and the received prefix must be probe-able for
/// resume.
fn stall_phase(server: &LfsServer) -> Result<()> {
    let authority = http::authority_of(&server.url())?;
    let id = "6".repeat(64);
    let mut stalled = TcpStream::connect(authority.as_str())?;
    let total = STALL_SENT * 2;
    write!(
        stalled,
        "PUT /packs/{id} HTTP/1.1\r\nhost: chaos\r\ncontent-length: {total}\r\n\
         content-range: bytes 0-{}/{total}\r\n\r\n",
        total - 1
    )?;
    stalled.write_all(&vec![9u8; STALL_SENT])?;
    stalled.flush()?;
    // Hold the socket open and silent; only the budget can cut it.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if server.metrics().timed_out >= 1 {
            break;
        }
        ensure!(
            Instant::now() < deadline,
            "stall phase: the stalled upload was never cut by the request budget"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    drop(stalled);
    let probe = http::roundtrip(&authority, &Request::new("HEAD", &format!("/packs/{id}")))?;
    let have: Option<u64> = probe.get_header("x-received").and_then(|v| v.parse().ok());
    ensure!(
        have == Some(STALL_SENT as u64),
        "stall phase: the cut upload's prefix was not persisted for resume (got {have:?})"
    );
    Ok(())
}

/// One actor of phase 3: put its payloads, push them, wait for the
/// fleet, fetch everyone's. Returns the thread's transfer stats.
fn run_chaos_actor(
    i: usize,
    url: String,
    seed: u64,
    objects: usize,
    actors: usize,
    gate: Arc<Barrier>,
) -> Result<(batch::TransferStats, TempDir)> {
    batch::reset_stats();
    let td = TempDir::new("chaos-actor")?;
    let store = LfsStore::open(td.path());
    let remote = HttpRemote::open(&url, Some(td.path()))?;
    let policy = RetryPolicy {
        max_attempts: 6,
        base: Duration::from_millis(40),
        cap: Duration::from_millis(400),
        seed: seed ^ (i as u64 + 1),
    };
    let prefetcher = Prefetcher {
        retry: policy,
        ..Prefetcher::default()
    };
    let mut mine = Vec::new();
    for j in 0..objects {
        mine.push(store.put(&payload(seed, i, j))?.0);
    }
    let pushed = prefetcher
        .push(&store, &remote, &mine)
        .with_context(|| format!("actor {i}: push under chaos"))?;
    ensure!(pushed.unavailable == 0, "actor {i}: push left objects behind");
    gate.wait();
    let everyone: Vec<Oid> = (0..actors)
        .flat_map(|a| (0..objects).map(move |j| Oid::of_bytes(&payload(seed, a, j))))
        .collect();
    let fetched = prefetcher
        .fetch(&remote, &store, &everyone)
        .with_context(|| format!("actor {i}: fetch under chaos"))?;
    ensure!(fetched.unavailable == 0, "actor {i}: fetch left objects behind");
    Ok((batch::stats(), td))
}

/// Run the whole chaos suite against one undersized hub. Convergence is
/// reported, not assumed: a divergent run returns `converged: false`
/// so the caller (CLI, gate) decides.
pub fn run_chaos(cfg: &ChaosConfig) -> Result<ChaosOutcome> {
    crate::init();
    ensure!(cfg.actors >= 2, "chaos needs at least two actors");
    ensure!(cfg.objects >= 1, "chaos needs at least one object per actor");
    eprintln!(
        "chaos: {} actors x {} objects, seed {} (replay: git-theta bench chaos {} {} {})",
        cfg.actors, cfg.objects, cfg.seed, cfg.actors, cfg.objects, cfg.seed
    );
    let t0 = Instant::now();

    // Deliberately undersized: two workers, two queue slots, a
    // sub-second budget. Everything that converges here converges
    // because of shedding, budgets, and retries — not headroom.
    let opts = ServeOptions {
        workers: 2,
        queue: 2,
        request_budget: Duration::from_millis(700),
        drain_deadline: Duration::from_secs(2),
        retry_after_secs: 0,
    };
    let td_hub = TempDir::new("chaos-hub")?;
    let server = LfsServer::spawn_with(td_hub.path(), "127.0.0.1:0", opts)?;
    let proxy = FaultProxy::spawn(&server.url())?;

    batch::reset_stats();
    overload_phase(&server, &opts, cfg.seed)?;
    stall_phase(&server)?;
    let probe_stats = batch::stats();

    // Phase 3: actor 0's traffic crosses the armed proxy.
    proxy.reject_next(REJECTS, 0);
    proxy.arm(FaultSpec::stall(Direction::Upload, ACTOR_STALL_AT, 1500));
    let gate = Arc::new(Barrier::new(cfg.actors));
    let mut handles = Vec::new();
    for i in 0..cfg.actors {
        let url = if i == 0 { proxy.url() } else { server.url() };
        let (seed, objects, actors, gate) = (cfg.seed, cfg.objects, cfg.actors, gate.clone());
        handles.push(std::thread::spawn(move || {
            run_chaos_actor(i, url, seed, objects, actors, gate).map_err(|e| format!("{e:#}"))
        }));
    }
    let mut actor_stats = Vec::new();
    let mut actor_dirs = Vec::new();
    for handle in handles {
        let (stats, td) = handle
            .join()
            .map_err(|_| anyhow!("a chaos actor panicked"))?
            .map_err(|e| anyhow!(e))?;
        actor_stats.push(stats);
        actor_dirs.push(td);
    }

    // Convergence proof: the hub store verifies, and every actor store
    // holds every payload byte-for-byte.
    let mut converged = true;
    let hub_store = LfsStore::at(&td_hub.path().join("lfs/objects"));
    for a in 0..cfg.actors {
        for j in 0..cfg.objects {
            let bytes = payload(cfg.seed, a, j);
            let oid = Oid::of_bytes(&bytes);
            if !matches!(hub_store.get(&oid), Ok(ref b) if *b == bytes) {
                eprintln!("chaos DIVERGED: hub lost or corrupted object {oid}");
                converged = false;
            }
            for (i, td) in actor_dirs.iter().enumerate() {
                let store = LfsStore::open(td.path());
                if !matches!(store.get(&oid), Ok(ref b) if *b == bytes) {
                    eprintln!("chaos DIVERGED: actor {i} lost or corrupted object {oid}");
                    converged = false;
                }
            }
        }
    }

    let fired = proxy.fired();
    ensure!(
        fired == REJECTS + 1,
        "chaos: expected exactly {} injected faults (rejects + stall), saw {fired}",
        REJECTS + 1
    );
    drop(proxy);
    let snap = server.shutdown(); // joins every worker — leaks hang here

    let mut out = ChaosOutcome {
        actors: cfg.actors,
        objects: cfg.objects,
        converged,
        accepted: snap.accepted,
        rejected: snap.rejected,
        timed_out: snap.timed_out,
        requests: snap.requests,
        in_flight_after_drain: snap.in_flight,
        sheds: probe_stats.sheds,
        backoff_retries: probe_stats.backoff_retries,
        resumed_bytes: probe_stats.resumed_bytes,
        faults_fired: fired,
        chaos_secs: 0.0,
    };
    for stats in &actor_stats {
        out.sheds += stats.sheds;
        out.backoff_retries += stats.backoff_retries;
        out.resumed_bytes += stats.resumed_bytes;
    }
    ensure!(
        out.sheds >= REJECTS + 1,
        "chaos: the proxy rejects and the overload probe must all register as sheds"
    );
    ensure!(out.backoff_retries >= out.sheds, "chaos: every shed is also a backoff retry");
    ensure!(out.in_flight_after_drain == 0, "chaos: drain left requests in flight");
    out.chaos_secs = t0.elapsed().as_secs_f64();
    Ok(out)
}

/// Human-readable summary of a chaos run.
pub fn render_chaos(out: &ChaosOutcome) -> String {
    format!(
        "chaos: {} actors x {} objects — {}\n\
         hub: {} accepted, {} shed, {} cut at budget, {} served, {} in flight after drain\n\
         clients: {} sheds absorbed, {} backoff retries, {} bytes resume skipped; \
         {} fault(s) injected; {:.2}s\n",
        out.actors,
        out.objects,
        if out.converged { "CONVERGED" } else { "DIVERGED" },
        out.accepted,
        out.rejected,
        out.timed_out,
        out.requests,
        out.in_flight_after_drain,
        out.sheds,
        out.backoff_retries,
        out.resumed_bytes,
        out.faults_fired,
        out.chaos_secs,
    )
}

/// Encode the run as the `BENCH_chaos.json` payload for the gate.
pub fn chaos_to_json(cfg: &ChaosConfig, out: &ChaosOutcome) -> Json {
    let mut root = JsonObj::new();
    root.insert("bench", "chaos");
    root.insert("actors", out.actors);
    root.insert("objects", out.objects);
    root.insert("seed", cfg.seed);
    root.insert("converged", u64::from(out.converged));
    root.insert("accepted", out.accepted);
    root.insert("rejected", out.rejected);
    root.insert("timed_out", out.timed_out);
    root.insert("requests", out.requests);
    root.insert("in_flight_after_drain", out.in_flight_after_drain);
    root.insert("sheds", out.sheds);
    root.insert("backoff_retries", out.backoff_retries);
    root.insert("resumed_bytes", out.resumed_bytes);
    root.insert("faults_fired", out.faults_fired);
    root.insert("chaos_secs", Json::Num(out.chaos_secs));
    Json::Obj(root)
}

/// `git-theta bench chaos [actors] [objects] [seed]`.
pub fn run_chaos_cli(args: &[String]) -> Result<()> {
    let cfg = ChaosConfig {
        actors: args.first().and_then(|s| s.parse().ok()).unwrap_or(4),
        objects: args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3),
        seed: args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0xC4A0_5EED),
    };
    let out = run_chaos(&cfg)?;
    print!("{}", render_chaos(&out));
    let path = write_bench_json("chaos", chaos_to_json(&cfg, &out))?;
    println!("wrote {}", path.display());
    ensure!(out.converged, "chaos seed {} did not converge", cfg.seed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payloads_are_deterministic_and_distinct() {
        assert_eq!(payload(7, 0, 0), payload(7, 0, 0));
        assert_ne!(payload(7, 0, 0), payload(7, 0, 1));
        assert_ne!(payload(7, 0, 0), payload(7, 1, 0));
        assert_ne!(payload(7, 0, 0), payload(8, 0, 0));
        assert!(payload(7, 0, 0).len() as u64 > ACTOR_STALL_AT);
    }

    #[test]
    fn tiny_chaos_run_converges_under_faults() {
        let cfg = ChaosConfig {
            actors: 2,
            objects: 2,
            seed: 23,
        };
        let out = run_chaos(&cfg).unwrap();
        assert!(out.converged, "tiny chaos run diverged");
        assert_eq!(out.faults_fired, REJECTS + 1);
        assert!(out.rejected >= 1);
        assert!(out.timed_out >= 1);
        assert!(out.sheds >= REJECTS + 1);
        assert!(out.backoff_retries >= out.sheds);
        assert!(out.resumed_bytes >= 1);
        assert_eq!(out.in_flight_after_drain, 0);
    }
}
