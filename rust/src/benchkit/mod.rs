//! Benchmark harness (criterion substitute) + the paper's workloads.
//!
//! [`Timer`]/[`Stats`] provide warmup + repeated measurement;
//! [`workflow`] implements the paper's six-commit community development
//! workflow (§4) over both Git LFS and Git-Theta; `benches/*.rs` are
//! thin `harness = false` wrappers that print each paper table/figure.

pub mod chaos;
pub mod checkout;
pub mod figure3;
pub mod merge;
pub mod replicate;
pub mod scenario;
pub mod transfer;
pub mod workflow;

use crate::util::json::Json;
use anyhow::Result;
use std::time::Instant;

/// Write a machine-readable benchmark record to `BENCH_<name>.json` in
/// the current directory (CI and the check script run from the repo
/// root, so successive runs overwrite in place and the perf trajectory
/// is trackable across PRs by diffing the file).
pub fn write_bench_json(name: &str, payload: Json) -> Result<std::path::PathBuf> {
    let path = std::path::PathBuf::from(format!("BENCH_{name}.json"));
    std::fs::write(&path, payload.to_string_pretty())?;
    Ok(path)
}

/// Summary statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Raw per-run measurements (seconds), in run order.
    pub samples: Vec<f64>,
}

impl Stats {
    /// Arithmetic mean of the samples (0 when empty).
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    /// Fastest sample (`+inf` when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Slowest sample (0 when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Population standard deviation of the samples.
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|s| (s - m) * (s - m))
            .sum::<f64>()
            / self.samples.len().max(1) as f64;
        var.sqrt()
    }
}

/// Time a closure `samples` times after `warmup` runs.
pub fn time_n<F: FnMut() -> Result<()>>(warmup: usize, samples: usize, mut f: F) -> Result<Stats> {
    for _ in 0..warmup {
        f()?;
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f()?;
        out.push(t0.elapsed().as_secs_f64());
    }
    Ok(Stats { samples: out })
}

/// Time a closure once, returning (elapsed seconds, result).
pub fn time_once<T, F: FnOnce() -> Result<T>>(f: F) -> Result<(f64, T)> {
    let t0 = Instant::now();
    let v = f()?;
    Ok((t0.elapsed().as_secs_f64(), v))
}

/// Render an aligned text table (the benches print paper-style rows).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, cell) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", cell, w = widths[i]));
        }
        line.trim_end().to_string() + "\n"
    };
    let header_cells: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&header_cells, &widths));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&"-".repeat(w + 2));
        sep.push('|');
    }
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// `git-theta bench <name>` entry point.
pub fn cli_bench(args: &[String]) -> Result<()> {
    let name = args.first().map(|s| s.as_str()).unwrap_or("help");
    match name {
        "table1" => workflow::run_table1_cli(&args[1..]),
        "figure2" => workflow::run_figure2_cli(&args[1..]),
        "figure3" => figure3::run_figure3_cli(&args[1..]),
        "transfer" => transfer::run_transfer_cli(&args[1..]),
        "checkout" => checkout::run_checkout_cli(&args[1..]),
        "merge" => merge::run_merge_cli(&args[1..]),
        "scenario" => scenario::run_scenario_cli(&args[1..]),
        "chaos" => chaos::run_chaos_cli(&args[1..]),
        "replicate" => replicate::run_replicate_cli(&args[1..]),
        _ => {
            println!(
                "benchmarks: table1, figure2, figure3, transfer, checkout, merge, \
                 scenario [actors ops seed faults], chaos [actors objects seed], \
                 replicate [objects seed] (full set lives in `cargo bench`)\n\
                 env: THETA_BENCH_PARAMS=<millions> scales the model"
            );
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats {
            samples: vec![1.0, 2.0, 3.0],
        };
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert!(s.stddev() > 0.0);
    }

    #[test]
    fn time_n_counts_samples() {
        let s = time_n(1, 5, || Ok(())).unwrap();
        assert_eq!(s.samples.len(), 5);
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["Commit", "Metric", "Git LFS", "Git-Theta"],
            &[
                vec!["Add T0".into(), "add".into(), "2m".into(), "14m".into()],
                vec!["CB LoRA".into(), "Size".into(), "11.4GB".into(), "0.27GB".into()],
            ],
        );
        assert!(t.contains("| Commit"));
        assert!(t.lines().count() == 4);
    }
}
