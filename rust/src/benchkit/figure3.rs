//! Figure 3: task performance across commit history, with real training.
//!
//! Reproduces the paper's Figure 3 *shape* on the synthetic CB/RTE/ANLI
//! tasks: few-shot LoRA training on CB, full fine-tunes on RTE (side
//! branch) and ANLI (main), then a native `git merge --strategy average`
//! — and evaluates every task at every commit. The full loop runs
//! through the VCS: each model version is committed with Git-Theta and
//! the merged model is produced by the merge *driver*, then read back
//! out of the repository for evaluation.

use crate::baseline::ThetaRepo;
use crate::checkpoint::{CheckpointFormat, SafetensorsFormat};
use crate::train::{ModelParams, SyntheticTask, TaskKind, Trainer};
use crate::util::tmp::TempDir;
use anyhow::{Context, Result};

/// Accuracy of one model version on the three tasks.
#[derive(Debug, Clone)]
pub struct Fig3Point {
    /// Short label of the commit this point was evaluated at.
    pub commit_label: &'static str,
    /// CB task accuracy.
    pub cb: f64,
    /// RTE task accuracy.
    pub rte: f64,
    /// ANLI task accuracy.
    pub anli: f64,
}

/// All evaluation points of one Figure 3 run, in commit order.
#[derive(Debug, Clone)]
pub struct Fig3Result {
    /// One accuracy triple per workflow commit.
    pub points: Vec<Fig3Point>,
}

const SHARED_SEED: u64 = 17;
const EVAL_BATCHES: usize = 8;

fn tasks(trainer: &Trainer) -> (SyntheticTask, SyntheticTask, SyntheticTask) {
    let v = trainer.cfg.vocab;
    let s = trainer.cfg.seq_len;
    (
        SyntheticTask::new(TaskKind::Cb, v, s, SHARED_SEED),
        SyntheticTask::new(TaskKind::Rte, v, s, SHARED_SEED),
        SyntheticTask::new(TaskKind::Anli, v, s, SHARED_SEED),
    )
}

fn eval_all(trainer: &Trainer, params: &ModelParams, label: &'static str) -> Result<Fig3Point> {
    let (cb, rte, anli) = tasks(trainer);
    Ok(Fig3Point {
        commit_label: label,
        cb: trainer.eval(params, &cb, EVAL_BATCHES)?.0,
        rte: trainer.eval(params, &rte, EVAL_BATCHES)?.0,
        anli: trainer.eval(params, &anli, EVAL_BATCHES)?.0,
    })
}

/// Run the Figure 3 experiment. Returns None when artifacts are absent.
pub fn run_figure3(steps: usize, lr: f32) -> Result<Option<Fig3Result>> {
    crate::init();
    let trainer = match Trainer::try_new()? {
        Some(t) => t,
        None => return Ok(None),
    };
    let td = TempDir::new("fig3")?;
    let repo = ThetaRepo::init(td.path(), "model.safetensors")?;
    let mut points = Vec::new();

    let commit_params = |repo: &ThetaRepo, params: &ModelParams, msg: &str| -> Result<()> {
        SafetensorsFormat.save_file(
            &params.to_checkpoint(),
            &repo.repo.worktree().join(&repo.model_path),
        )?;
        repo.add()?;
        repo.commit(msg)?;
        Ok(())
    };
    let read_params = |repo: &ThetaRepo| -> Result<ModelParams> {
        let ck = repo.read_model()?;
        ModelParams::from_checkpoint(&ck, &trainer.cfg.param_names)
    };

    // Commit 1: base "pre-trained" model. Give it brief multitask
    // exposure (the T0 stand-in): a few steps on a CB/ANLI mixture.
    let mut params = trainer.init_params()?;
    let (mut cb, mut rte, mut anli) = tasks(&trainer);
    trainer.train(&mut params, &mut cb, steps / 4, lr)?;
    trainer.train(&mut params, &mut anli, steps / 4, lr)?;
    commit_params(&repo, &params, "Add base model")?;
    points.push(eval_all(&trainer, &params, "base")?);

    // Commit 2: LoRA few-shot training on CB, merged into the weights
    // (the clean filter then stores it as a low-rank update).
    let mut lora = trainer.init_lora()?;
    trainer.train_lora(&params, &mut lora, &mut cb, steps, lr)?;
    let cb_params = trainer.merge_lora(&params, &lora, trainer.cfg.lora_rank as f32)?;
    commit_params(&repo, &cb_params, "Train on CB with LoRA")?;
    points.push(eval_all(&trainer, &cb_params, "cb-lora")?);

    // Commit 3: full fine-tune on RTE, on a side branch.
    repo.repo.create_branch("rte")?;
    repo.checkout("rte")?;
    let mut rte_params = read_params(&repo)?;
    trainer.train(&mut rte_params, &mut rte, steps, lr)?;
    commit_params(&repo, &rte_params, "Fine-Tune on RTE")?;
    points.push(eval_all(&trainer, &rte_params, "rte-branch")?);

    // Commit 4: full fine-tune on ANLI, on main.
    repo.checkout("main")?;
    let mut anli_params = read_params(&repo)?;
    trainer.train(&mut anli_params, &mut anli, steps, lr)?;
    commit_params(&repo, &anli_params, "Fine-Tune on ANLI")?;
    points.push(eval_all(&trainer, &anli_params, "anli-main")?);

    // Commit 5: merge the RTE branch into main by parameter averaging —
    // through the actual merge driver.
    repo.merge_with_strategy("rte", "average")?;
    let merged = read_params(&repo)?;
    commit_params(&repo, &merged, "noop")?; // model already in worktree
    points.push(eval_all(&trainer, &merged, "merged")?);

    Ok(Some(Fig3Result { points }))
}

/// Render the Figure 3 table + qualitative checks.
pub fn render_figure3(r: &Fig3Result) -> String {
    let mut rows = Vec::new();
    for p in &r.points {
        rows.push(vec![
            p.commit_label.to_string(),
            format!("{:.3}", p.cb),
            format!("{:.3}", p.rte),
            format!("{:.3}", p.anli),
        ]);
    }
    let mut out = super::render_table(&["Commit", "CB acc", "RTE acc", "ANLI acc"], &rows);
    let by = |label: &str| r.points.iter().find(|p| p.commit_label == label);
    if let (Some(anli), Some(merged), Some(rte)) =
        (by("anli-main"), by("merged"), by("rte-branch"))
    {
        out.push_str(&format!(
            "\nmerge effect on RTE: anli-only {:.3} -> merged {:.3} (rte-branch {:.3})\n",
            anli.rte, merged.rte, rte.rte
        ));
        out.push_str(if merged.rte > anli.rte {
            "=> merging the RTE branch improved RTE on main (paper Figure 3 shape reproduced)\n"
        } else {
            "=> WARNING: merge did not improve RTE at this scale/seed\n"
        });
    }
    out
}

/// `git-theta bench figure3` entry point.
pub fn run_figure3_cli(args: &[String]) -> Result<()> {
    let steps: usize = args
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::env::var("THETA_FIG3_STEPS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(600)
        });
    let result = run_figure3(steps, 0.1)?
        .context("artifacts not built: run `make artifacts` first")?;
    println!("{}", render_figure3(&result));
    Ok(())
}
