//! Merge-engine ablation: conflict-resolution cost with each of the
//! engine's four levers toggled independently.
//!
//! Synthesizes a three-way merge over a model whose parameter groups
//! carry deep incremental chains (a continually-trained ancestor) and
//! split four ways: genuinely conflicted on both branches, changed on
//! one side only, value-equal-but-re-anchored (the change-skipping
//! lever's prey), and untouched. The chains live only on an LFS
//! *remote*; every measured run starts from an empty local store, so
//! the batched-prefetch lever is exercised against real per-object
//! fetch traffic.
//!
//! Measured per configuration: merge wall-clock, peak transient heap
//! (when the running binary installed
//! [`TrackingAlloc`](crate::util::alloc)), and transfer round trips.
//! **Merged-output parity is asserted on every sample**: each
//! configuration's merged metadata must smudge to exactly the
//! checkpoint the serial baseline produces, so a config that "wins" by
//! resolving garbage cannot pass.

use super::{render_table, Stats};
use crate::checkpoint::Checkpoint;
use crate::gitcore::drivers::MergeOptions;
use crate::lfs::{batch, LfsRemote, LfsStore};
use crate::tensor::Tensor;
use crate::theta::checkout::snapshot_metadata;
use crate::theta::filter::{clean_checkpoint_opts, smudge_metadata, CleanOptions, ObjectAccess};
use crate::theta::merge::{merge_metadata_opts, EngineOptions};
use crate::theta::metadata::ModelMetadata;
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use crate::util::{alloc, humansize, par};
use anyhow::{ensure, Result};
use std::time::Instant;

/// One measured merge configuration.
#[derive(Debug, Clone)]
pub struct MergeRun {
    /// Which levers were on.
    pub label: &'static str,
    /// Mean merge wall-clock seconds (each sample from a cold local store).
    pub merge_secs: f64,
    /// Peak transient heap of one merge, when the binary tracks it.
    pub peak_bytes: Option<usize>,
    /// Transfer round trips of one merge (negotiations + packs +
    /// per-object requests).
    pub round_trips: u64,
    /// Conflicts resolved by a strategy.
    pub resolved: usize,
    /// Conflicts auto-resolved by LSH value-equality.
    pub value_skipped: usize,
    /// Reconstruction-cache hits.
    pub cache_hits: u64,
}

/// The synthesized three-way merge inputs plus the checkpoint every
/// configuration's merged output must smudge back to.
pub struct MergeFixture {
    /// Directory whose `lfs/objects` holds every chain object; served
    /// to measured runs as the LFS remote.
    remote_dir: TempDir,
    /// The merge base: every group at chain depth `depth`.
    pub ancestor: ModelMetadata,
    /// Our branch: conflict + ours-only groups changed, skip-range
    /// groups re-anchored densely (values untouched).
    pub ours: ModelMetadata,
    /// Their branch: conflict + theirs-only groups changed, skip-range
    /// groups bumped then reverted (deeper chain, values untouched).
    pub theirs: ModelMetadata,
    /// The checkpoint the serial baseline's merge smudges to.
    pub expect: Checkpoint,
    /// Parameter groups in the model.
    pub groups: usize,
    /// f32 elements per group.
    pub elems: usize,
    /// Ancestor chain depth.
    pub depth: usize,
}

impl MergeFixture {
    /// A fresh [`ObjectAccess`] whose local store is empty and whose
    /// remote serves the fixture's objects. Every measured sample gets
    /// its own so prefetch/fetch costs are actually paid.
    pub fn fresh_access(&self) -> Result<(ObjectAccess, TempDir)> {
        let td = TempDir::new("bench-merge-local")?;
        let access = ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: Some(Box::new(LfsRemote::open(self.remote_dir.path()))),
        };
        Ok((access, td))
    }

    fn merge_opts() -> MergeOptions {
        MergeOptions {
            strategy: Some("average".into()),
            per_group: vec![],
            verbose: false,
        }
    }
}

/// Synthesize the fixture: `groups`×`elems` model, ancestor chains
/// `depth` deep, groups split into conflict / ours-only / theirs-only /
/// value-equal quarters.
pub fn build_fixture(depth: usize, groups: usize, elems: usize) -> Result<MergeFixture> {
    ensure!(depth >= 2 && groups >= 1 && elems >= 64, "fixture too small");
    let remote_dir = TempDir::new("bench-merge-remote")?;
    // Build chains directly into the remote's store; measured runs must
    // fetch them.
    let build = ObjectAccess {
        store: LfsStore::at(&remote_dir.path().join("lfs/objects")),
        remote: None,
    };
    let threads = par::default_threads();
    let opts = CleanOptions {
        snapshot_depth: None,
        threads,
        ..Default::default()
    };

    let name = |g: usize| format!("block{g}/w");
    let mut rng = Pcg64::new(0x3E26E);
    let mut ck = Checkpoint::new();
    for g in 0..groups {
        let vals: Vec<f32> = (0..elems).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
        ck.insert(name(g), Tensor::from_f32(vec![elems], vals)?);
    }
    let mut meta = clean_checkpoint_opts(&build, &ck, "native", None, &opts)?;
    for v in 1..depth {
        // Touch ~1/64 of each group per version: sparse links all the
        // way down, exactly the continually-trained pathology.
        for g in 0..groups {
            let n = name(g);
            let mut vals = ck.get(&n).unwrap().to_f32_vec()?;
            for k in 0..(elems / 64).max(1) {
                let at = (v * 31 + k * 97 + g * 13) % elems;
                vals[at] = (rng.next_f32() - 0.5) * 0.2;
            }
            ck.insert(n, Tensor::from_f32(vec![elems], vals)?);
        }
        meta = clean_checkpoint_opts(&build, &ck, "native", Some(&meta), &opts)?;
    }
    let ancestor = meta;
    let anc_ck = ck;

    // Group quarters.
    let c = (groups / 4).max(1);
    let conflict = 0..c.min(groups);
    let ours_only = c.min(groups)..(2 * c).min(groups);
    let theirs_only = (2 * c).min(groups)..(3 * c).min(groups);
    let skip = (3 * c).min(groups)..groups;

    // Their branch. Step 1: bump the skip-range groups...
    let mut their_ck = anc_ck.clone();
    for g in skip.clone() {
        let n = name(g);
        let mut vals = their_ck.get(&n).unwrap().to_f32_vec()?;
        vals[0] = 7.5;
        their_ck.insert(n, Tensor::from_f32(vec![elems], vals)?);
    }
    let their_step = clean_checkpoint_opts(&build, &their_ck, "native", Some(&ancestor), &opts)?;
    // ...step 2: restore them verbatim (values now exactly the
    // ancestor's, chain two links deeper) and apply the real changes.
    for g in skip.clone() {
        let n = name(g);
        their_ck.insert(n.clone(), anc_ck.get(&n).unwrap().clone());
    }
    for g in conflict.clone().chain(theirs_only) {
        let n = name(g);
        let mut vals = their_ck.get(&n).unwrap().to_f32_vec()?;
        vals[1] += 1.0;
        vals[elems - 1] -= 2.0;
        their_ck.insert(n, Tensor::from_f32(vec![elems], vals)?);
    }
    let theirs = clean_checkpoint_opts(&build, &their_ck, "native", Some(&their_step), &opts)?;

    // Our branch: different changes on the conflict + ours-only ranges,
    // then a dense re-anchor of the skip range (values untouched).
    let mut our_ck = anc_ck.clone();
    for g in conflict.chain(ours_only) {
        let n = name(g);
        let mut vals = our_ck.get(&n).unwrap().to_f32_vec()?;
        vals[2] -= 3.0;
        vals[elems / 2] += 0.5;
        our_ck.insert(n, Tensor::from_f32(vec![elems], vals)?);
    }
    let mut ours = clean_checkpoint_opts(&build, &our_ck, "native", Some(&ancestor), &opts)?;
    if !skip.is_empty() {
        let mut sub = ModelMetadata::new("native");
        for g in skip {
            let n = name(g);
            sub.groups.insert(n.clone(), ours.groups[&n].clone());
        }
        let (snapped, _) = snapshot_metadata(&build, &sub, threads)?;
        for (n, entry) in snapped.groups {
            ours.groups.insert(n, entry);
        }
    }

    // The reference output: serial merge, smudged once.
    let (serial, _) = merge_metadata_opts(
        &build,
        Some(&ancestor),
        &ours,
        &theirs,
        &MergeFixture::merge_opts(),
        &EngineOptions::serial(),
    )?;
    let expect = smudge_metadata(&build, &serial, threads)?;

    Ok(MergeFixture {
        remote_dir,
        ancestor,
        ours,
        theirs,
        expect,
        groups,
        elems,
        depth,
    })
}

/// Measure one configuration: `samples` cold merges (parity asserted
/// on each), one serial stats pass for round trips, and one
/// allocation-tracked merge when the binary tracks the heap.
fn measure(
    label: &'static str,
    fixture: &MergeFixture,
    engine: &EngineOptions,
) -> Result<MergeRun> {
    let opts = MergeFixture::merge_opts();
    let mut samples = Vec::new();
    let mut resolved = 0;
    let mut value_skipped = 0;
    let mut cache_hits = 0;
    for _ in 0..3 {
        let (access, _td) = fixture.fresh_access()?;
        let t0 = Instant::now();
        let (merged, stats) = merge_metadata_opts(
            &access,
            Some(&fixture.ancestor),
            &fixture.ours,
            &fixture.theirs,
            &opts,
            engine,
        )?;
        samples.push(t0.elapsed().as_secs_f64());
        resolved = stats.resolved.len();
        value_skipped = stats.value_skipped;
        cache_hits = stats.cache_hits;
        // Parity: the merged output must smudge to exactly what the
        // serial baseline produced.
        let threads = par::default_threads();
        ensure!(
            smudge_metadata(&access, &merged, threads)? == fixture.expect,
            "config '{label}' merged a different checkpoint"
        );
    }

    // Round trips counted with a single-threaded engine: transfer
    // counters are thread-local, and worker-thread lazy fetches would
    // otherwise escape the orchestrating thread's counters. The fetch
    // *set* is thread-count-independent, so this is exact.
    let (access, _td) = fixture.fresh_access()?;
    batch::reset_stats();
    merge_metadata_opts(
        &access,
        Some(&fixture.ancestor),
        &fixture.ours,
        &fixture.theirs,
        &opts,
        &EngineOptions {
            threads: 1,
            ..engine.clone()
        },
    )?;
    let round_trips = batch::stats().round_trips();

    let peak_bytes = if alloc::active() {
        let (access, _td) = fixture.fresh_access()?;
        let base = alloc::reset_peak();
        merge_metadata_opts(
            &access,
            Some(&fixture.ancestor),
            &fixture.ours,
            &fixture.theirs,
            &opts,
            engine,
        )?;
        Some(alloc::peak_bytes().saturating_sub(base))
    } else {
        None
    };

    Ok(MergeRun {
        label,
        merge_secs: Stats { samples }.mean(),
        peak_bytes,
        round_trips,
        resolved,
        value_skipped,
        cache_hits,
    })
}

/// Run the full ablation: serial baseline, each lever alone, all on.
pub fn run_ablation(fixture: &MergeFixture) -> Result<Vec<MergeRun>> {
    let serial = EngineOptions::serial();
    let threads = par::default_threads();
    let configs: Vec<(&'static str, EngineOptions)> = vec![
        ("serial", serial.clone()),
        (
            "+cache",
            EngineOptions {
                cache: true,
                ..serial.clone()
            },
        ),
        (
            "+parallel",
            EngineOptions {
                threads,
                ..serial.clone()
            },
        ),
        (
            "+prefetch",
            EngineOptions {
                prefetch: true,
                ..serial.clone()
            },
        ),
        (
            "+skip",
            EngineOptions {
                value_skip: true,
                ..serial
            },
        ),
        ("all on", EngineOptions::default()),
    ];
    configs
        .into_iter()
        .map(|(label, engine)| measure(label, fixture, &engine))
        .collect()
}

/// Render the ablation as a paper-style table.
pub fn render_runs(fixture: &MergeFixture, runs: &[MergeRun]) -> String {
    let baseline = runs.first().map(|r| r.merge_secs).unwrap_or(0.0);
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.label.to_string(),
                humansize::duration(r.merge_secs),
                match r.peak_bytes {
                    Some(b) => humansize::bytes(b as u64),
                    None => "n/a".to_string(),
                },
                r.round_trips.to_string(),
                r.resolved.to_string(),
                r.value_skipped.to_string(),
                r.cache_hits.to_string(),
                format!("{:.2}x", baseline / r.merge_secs.max(1e-12)),
            ]
        })
        .collect();
    format!(
        "Merge ablation: {} groups x {} f32 elems, chains {} deep\n{}",
        fixture.groups,
        fixture.elems,
        fixture.depth,
        render_table(
            &[
                "Engine config",
                "Merge",
                "Peak alloc",
                "Round trips",
                "Resolved",
                "Skipped",
                "Cache hits",
                "Speedup",
            ],
            &rows,
        )
    )
}

/// Encode the ablation as the machine-readable `BENCH_merge.json`
/// payload (perf trajectory tracking across PRs).
pub fn runs_to_json(fixture: &MergeFixture, runs: &[MergeRun]) -> Json {
    let baseline = runs.first().map(|r| r.merge_secs).unwrap_or(0.0);
    let mut root = JsonObj::new();
    root.insert("bench", "merge");
    root.insert("depth", fixture.depth);
    root.insert("groups", fixture.groups);
    root.insert("elems", fixture.elems);
    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            let mut o = JsonObj::new();
            o.insert("label", r.label);
            o.insert("merge_secs", Json::Num(r.merge_secs));
            o.insert(
                "peak_bytes",
                match r.peak_bytes {
                    Some(b) => Json::from(b),
                    None => Json::Null,
                },
            );
            o.insert("round_trips", r.round_trips);
            o.insert("resolved", r.resolved);
            o.insert("value_skipped", r.value_skipped);
            o.insert("cache_hits", r.cache_hits);
            o.insert(
                "speedup_vs_serial",
                Json::Num(baseline / r.merge_secs.max(1e-12)),
            );
            Json::Obj(o)
        })
        .collect();
    root.insert("runs", Json::Arr(rows));
    Json::Obj(root)
}

/// `git-theta bench merge [depth] [groups] [elems]` entry point.
pub fn run_merge_cli(args: &[String]) -> Result<()> {
    let depth = args.first().and_then(|s| s.parse().ok()).unwrap_or(8usize);
    let groups = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64usize);
    let elems = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16_384usize);
    let fixture = build_fixture(depth, groups, elems)?;
    println!(
        "three-way fixture built: chains {depth} deep on the remote; \
         merged-output parity asserted on every sample"
    );
    let runs = run_ablation(&fixture)?;
    print!("{}", render_runs(&fixture, &runs));
    let path = super::write_bench_json("merge", runs_to_json(&fixture, &runs))?;
    println!("wrote {}", path.display());
    if let (Some(serial), Some(all_on)) = (runs.first(), runs.last()) {
        println!(
            "all-on vs serial: {:.2}x merge speedup, {} -> {} round trips",
            serial.merge_secs / all_on.merge_secs.max(1e-12),
            serial.round_trips,
            all_on.round_trips
        );
    }
    if !alloc::active() {
        println!("note: peak-alloc tracking inactive (this binary did not install TrackingAlloc)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_small_fixture_end_to_end() {
        // Small but structurally complete: conflicts, one-sided
        // changes, and value-equal re-anchors all present; parity is
        // asserted inside measure() for every row.
        let fixture = build_fixture(4, 8, 256).unwrap();
        let max_depth = fixture
            .ancestor
            .groups
            .values()
            .map(|g| g.chain_depth())
            .max()
            .unwrap();
        assert_eq!(max_depth, 4);
        let runs = run_ablation(&fixture).unwrap();
        assert_eq!(runs.len(), 6);
        let by_label = |l: &str| runs.iter().find(|r| r.label == l).unwrap();

        // The skip lever resolves the re-anchored quarter without a
        // strategy; everyone else sends those groups to `average`.
        assert!(by_label("+skip").value_skipped >= 1);
        assert!(by_label("serial").value_skipped == 0);
        assert!(by_label("serial").resolved > by_label("+skip").resolved);
        // The cache lever reuses the shared ancestor prefix.
        assert!(by_label("+cache").cache_hits >= 1);
        assert_eq!(by_label("serial").cache_hits, 0);
        // Batched prefetch collapses round trips vs lazy per-object.
        assert!(by_label("+prefetch").round_trips < by_label("serial").round_trips);

        let table = render_runs(&fixture, &runs);
        assert!(table.contains("all on"));
        assert!(table.contains("Round trips"));
    }

    #[test]
    fn json_payload_roundtrips() {
        let fixture = build_fixture(2, 4, 128).unwrap();
        let runs = run_ablation(&fixture).unwrap();
        let json = runs_to_json(&fixture, &runs);
        let text = json.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("bench").and_then(|v| v.as_str()), Some("merge"));
        assert_eq!(
            back.get("runs").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(6)
        );
    }
}
