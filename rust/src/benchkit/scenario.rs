//! Collaboration-at-scale scenario harness: N concurrent collaborator
//! actors — each a real clone in a tempdir — drive a weighted op mix
//! (train-step, push, pull, branch+merge, fine-tune, clean, snapshot,
//! gc) against
//! one served hub ([`LfsServer`]), with one actor's traffic crossing
//! the [`FaultProxy`] so mid-pack kills can be injected into live
//! scenario steps.
//!
//! The run is **seeded and replayable**: every actor's op sequence is
//! a pure function of `(scenario seed, actor index)`, the seed is
//! printed on every run, and on divergence the full per-actor op trace
//! is dumped next to the bench output. Thread interleaving still
//! varies between runs — counters like push retries are contention
//! measurements, not constants — but the op schedule, and therefore
//! what each actor *tried* to do, replays exactly.
//!
//! After the op phase a deterministic **fault phase** kills a fetch
//! mid-pack through the proxy (the actor must retry, resume from the
//! partial, and converge), then a **quiesce phase** drives every clone
//! through fetch → merge → push rounds until the whole fleet sits on
//! one hub tip. Convergence is then *proved*, not assumed: every
//! clone's checked-out parameter groups must be byte-identical, a
//! fresh verification clone from the hub must reproduce the same
//! bytes, and every object in the hub store must re-hash to its id.
//!
//! Contention counters (push retries, merge commits under load,
//! gc spares, transfer round trips, store directory scans,
//! [`TrackingAlloc`](crate::util::alloc::TrackingAlloc) peak) are
//! emitted as `BENCH_scenario.json` and locked in
//! `scripts/bench_baseline.json`. See `docs/TESTING.md`.

use super::write_bench_json;
use crate::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
use crate::gitcore::attributes::Attributes;
use crate::gitcore::drivers::MergeOptions;
use crate::gitcore::object::Oid;
use crate::gitcore::remote::RemoteSpec;
use crate::gitcore::repo::Repository;
use crate::lfs::faults::{Direction, FaultProxy, FaultSpec};
use crate::lfs::{batch, open_transport, LfsServer, LfsStore};
use crate::tensor::Tensor;
use crate::theta::hooks::referenced_lfs_oids;
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use crate::util::{alloc, humansize};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::time::Instant;

/// The one tracked model every collaborator trains.
const MODEL_PATH: &str = "model.safetensors";
/// Parameter groups in the shared model.
const GROUPS: usize = 4;
/// f32 elements per group (small: contention, not volume, is measured).
const ELEMS: usize = 128;
/// Perturbation scale per train step — far above any LSH
/// change-detection threshold, so every train step genuinely commits.
const TRAIN_SIGMA: f32 = 0.05;
/// Push attempts before an actor declares the hub unreachable. Every
/// retry first fetches + merges the tip that beat it, so forward
/// progress is guaranteed unless the hub moves faster than the actor
/// can merge for this many consecutive rounds.
const PUSH_ATTEMPTS: usize = 32;
/// Byte offset of the injected mid-pack kill in the fault phase; any
/// freshly trained group object makes the pack comfortably larger.
const KILL_AT: u64 = 64;

/// Scenario shape. All runs with equal configs schedule identical
/// per-actor op sequences.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioConfig {
    /// Concurrent collaborator clones.
    pub actors: usize,
    /// Total ops across all actors (split as evenly as possible).
    pub ops: usize,
    /// Master seed; actor i derives its RNG from `(seed, i)`.
    pub seed: u64,
    /// Mid-pack fetch kills injected after the op phase.
    pub faults: usize,
}

/// Per-actor results: contention counters plus the replayable trace.
#[derive(Debug, Clone, Default)]
pub struct ActorStats {
    /// Ops this actor completed (out of its scheduled share).
    pub ops_applied: usize,
    /// Pushes that landed on the hub.
    pub pushes: u64,
    /// Pushes rejected by hub contention that fetched, merged, retried.
    pub push_retries: u64,
    /// True merge commits created (fast-forwards excluded).
    pub merge_commits: u64,
    /// `gc --prune` runs on this actor's clone.
    pub gc_runs: u64,
    /// Objects gc spared across those runs (staged/recent reachability).
    pub gc_spared: u64,
    /// Fine-tune ops completed (branch → train → snapshot → merge → push).
    pub finetunes: u64,
    /// Thread-local transfer round trips this actor performed.
    pub round_trips: u64,
    /// Bytes this actor put on the wire.
    pub wire_bytes: u64,
    /// Store directory scans this actor's transfers cost.
    pub dir_scans: u64,
    /// One line per op: `a<idx> op<n> <kind>` — the replay trace.
    pub trace: Vec<String>,
}

/// Whole-scenario outcome: the convergence verdict plus aggregated
/// contention counters (actors + the coordinator thread).
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    /// Concurrent collaborator clones the run drove.
    pub actors: usize,
    /// Total ops the config scheduled across the fleet.
    pub ops_requested: usize,
    /// Ops the fleet actually completed.
    pub ops_applied: usize,
    /// All clones byte-identical + hub store verified.
    pub converged: bool,
    /// Injected mid-pack kills that actually fired.
    pub faults_fired: u64,
    /// Fetches that were killed mid-pack and had to retry+resume.
    pub fetch_retries: u64,
    /// Pushes that landed on the hub (fleet + coordinator).
    pub pushes: u64,
    /// Contention-rejected pushes that fetched, merged, and retried.
    pub push_retries: u64,
    /// True merge commits created fleet-wide (fast-forwards excluded).
    pub merge_commits: u64,
    /// `gc --prune` runs across the fleet.
    pub gc_runs: u64,
    /// Objects gc spared across those runs.
    pub gc_spared: u64,
    /// Fine-tune ops completed fleet-wide.
    pub finetunes: u64,
    /// Fetch→merge→push rounds until the fleet sat on one hub tip.
    pub quiesce_rounds: u64,
    /// Transfer round trips (negotiations + packs + object copies).
    pub round_trips: u64,
    /// Total bytes the fleet put on the wire.
    pub wire_bytes: u64,
    /// Store directory scans the run cost.
    pub dir_scans: u64,
    /// Hub store objects that re-hashed to their id in the verify pass.
    pub store_objects_verified: usize,
    /// 0 when no tracking allocator is installed (library tests).
    pub peak_heap_bytes: u64,
    /// Wall-clock seconds for the whole run.
    pub scenario_secs: f64,
    /// Per-actor op traces (deterministic per seed) for replay checks.
    pub traces: Vec<Vec<String>>,
}

// ---------------------------------------------------------------------
// model helpers
// ---------------------------------------------------------------------

fn base_model(seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut ck = Checkpoint::new();
    for g in 0..GROUPS {
        let vals: Vec<f32> = (0..ELEMS).map(|_| rng.next_gaussian() as f32 * 0.02).collect();
        ck.insert(
            format!("layer_{g}/weight"),
            Tensor::from_f32(vec![ELEMS], vals).unwrap(),
        );
    }
    ck
}

fn load_model(repo: &Repository) -> Result<Checkpoint> {
    SafetensorsFormat.load_file(&repo.worktree().join(MODEL_PATH))
}

fn save_model(repo: &Repository, ck: &Checkpoint) -> Result<()> {
    SafetensorsFormat.save_file(ck, &repo.worktree().join(MODEL_PATH))
}

/// Perturb one randomly chosen parameter group in place (a train step
/// touches a subset of the model, so concurrent actors sometimes
/// conflict on a group and sometimes merge trivially).
fn perturb(ck: &mut Checkpoint, rng: &mut Pcg64) {
    let names: Vec<String> = ck.iter().map(|(n, _)| n.clone()).collect();
    let name = names[rng.below(names.len() as u64) as usize].clone();
    let t = ck.get(&name).unwrap();
    let shape = t.shape().to_vec();
    let mut vals = t.to_f32_vec().unwrap();
    for v in &mut vals {
        *v += rng.next_gaussian() as f32 * TRAIN_SIGMA;
    }
    ck.insert(name, Tensor::from_f32(shape, vals).unwrap());
}

// ---------------------------------------------------------------------
// collaborator ops
// ---------------------------------------------------------------------

fn avg_opts() -> MergeOptions {
    MergeOptions {
        strategy: Some("average".to_string()),
        per_group: Vec::new(),
        verbose: false,
    }
}

/// Merge a fetched remote tip into the local HEAD (parameter conflicts
/// resolve by averaging). Counts real merge commits, not FFs.
fn merge_tip(repo: &Repository, tip: Oid, actor: &str, stats: &mut ActorStats) -> Result<()> {
    if repo.head_commit()? == Some(tip) {
        return Ok(());
    }
    let report = repo
        .merge(&tip.to_hex(), &avg_opts(), actor)
        .with_context(|| format!("{actor}: merging remote tip {}", tip.short()))?;
    if report.commit.is_some() && !report.fast_forward && !report.already_up_to_date {
        stats.merge_commits += 1;
    }
    Ok(())
}

/// Push with the contention-retry loop: a rejection because the hub
/// moved (either detected locally or by the server's compare-and-set)
/// fetches the winning tip, merges it, and tries again.
fn push_with_retry(
    repo: &Repository,
    spec: &RemoteSpec,
    actor: &str,
    stats: &mut ActorStats,
) -> Result<()> {
    for _ in 0..PUSH_ATTEMPTS {
        match repo.push_spec(spec, "main") {
            Ok(_) => {
                stats.pushes += 1;
                return Ok(());
            }
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains("fetch first") || msg.contains("moved during the push") {
                    stats.push_retries += 1;
                    let tip = repo.fetch_head_spec(spec, "main")?;
                    merge_tip(repo, tip, actor, stats)?;
                } else {
                    return Err(e);
                }
            }
        }
    }
    bail!("{actor}: push did not land after {PUSH_ATTEMPTS} attempts")
}

/// Download every parameter-group object the current HEAD references
/// but the local store is missing, as one pack. Returns objects moved.
fn prefetch_groups(repo: &Repository, spec: &RemoteSpec) -> Result<u64> {
    let head = match repo.head_commit()? {
        Some(h) => h,
        None => return Ok(0),
    };
    let tree = repo.odb().read_tree(&repo.odb().read_commit(&head)?.tree)?;
    let local = LfsStore::open(repo.theta_dir());
    let missing: Vec<Oid> = referenced_lfs_oids(repo, &tree)?
        .into_iter()
        .filter(|o| !local.contains(o))
        .collect();
    if missing.is_empty() {
        return Ok(0);
    }
    let remote = open_transport(spec, Some(repo.theta_dir()))?;
    let summary = batch::fetch_pack(remote.as_ref(), &local, &missing)?;
    ensure!(summary.unavailable == 0, "prefetch left {} objects behind", summary.unavailable);
    Ok(summary.objects as u64)
}

/// Pull op: fetch the hub tip without moving refs, merge it (handles
/// both fast-forward and true divergence), then prefetch the referenced
/// group objects so pack streams actually cross the wire.
fn pull_op(
    repo: &Repository,
    spec: &RemoteSpec,
    actor: &str,
    stats: &mut ActorStats,
) -> Result<()> {
    let tip = repo.fetch_head_spec(spec, "main")?;
    merge_tip(repo, tip, actor, stats)?;
    prefetch_groups(repo, spec)?;
    Ok(())
}

/// Train step: perturb one group, clean (add), commit.
fn train_op(repo: &Repository, rng: &mut Pcg64, actor: &str) -> Result<Oid> {
    let mut ck = load_model(repo)?;
    perturb(&mut ck, rng);
    save_model(repo, &ck)?;
    repo.add(&[MODEL_PATH])?;
    repo.commit("train step", actor)
}

/// Clean op: perturb + stage through the clean filter, no commit (the
/// staged-but-uncommitted state gc and later commits must respect).
fn clean_op(repo: &Repository, rng: &mut Pcg64) -> Result<()> {
    let mut ck = load_model(repo)?;
    perturb(&mut ck, rng);
    save_model(repo, &ck)?;
    repo.add(&[MODEL_PATH])
}

/// Branch op: fork, train on the branch, train on main (so both sides
/// diverge), then merge the branch back with parameter averaging.
fn branch_merge_op(
    repo: &Repository,
    rng: &mut Pcg64,
    actor: &str,
    branch_n: u64,
    stats: &mut ActorStats,
) -> Result<()> {
    let name = format!("{actor}-b{branch_n}");
    repo.create_branch(&name)?;
    repo.checkout(&name)?;
    train_op(repo, rng, actor)?;
    repo.checkout("main")?;
    train_op(repo, rng, actor)?;
    let report = repo.merge(&name, &avg_opts(), actor)?;
    if report.commit.is_some() && !report.fast_forward && !report.already_up_to_date {
        stats.merge_commits += 1;
    }
    Ok(())
}

/// Snapshot op: re-anchor the staged (or committed) metadata's update
/// chains to dense snapshots and commit the result (`git-theta
/// snapshot` followed by a commit).
fn snapshot_op(repo: &Repository, actor: &str) -> Result<()> {
    let staged = match repo.prior_staged(MODEL_PATH)? {
        Some(s) => s,
        None => return Ok(()),
    };
    if !crate::theta::ModelMetadata::is_metadata(&staged) {
        return Ok(());
    }
    let access = crate::theta::ObjectAccess::for_repo(repo)?;
    let meta = crate::theta::ModelMetadata::from_bytes(&staged)?;
    let (snap, report) = crate::theta::snapshot_metadata(&access, &meta, 1)?;
    if report.reanchored == 0 {
        return Ok(()); // every chain already dense
    }
    let index = crate::gitcore::index::Index::load(repo.theta_dir())?;
    let raw = match index.get(MODEL_PATH) {
        Some(entry) => entry.raw,
        None => {
            let ck = crate::theta::smudge_metadata(&access, &snap, 1)?;
            Oid::of_bytes(&SafetensorsFormat.save_bytes(&ck)?)
        }
    };
    repo.add_staged_bytes(MODEL_PATH, snap.to_bytes(), raw)?;
    repo.commit("snapshot", actor)?;
    Ok(())
}

/// Fine-tune op: fork a feature branch, take a train step on it,
/// re-anchor the result with `snapshot` (giving the chain a fresh
/// dense base — exactly the shape the chain-aware wire negotiation
/// dedups against), fold the branch back into main, and push. The
/// push exercises chain negotiation under concurrency and, when the
/// hub moved meanwhile, the CAS-push retry loop.
fn finetune_op(
    repo: &Repository,
    spec: &RemoteSpec,
    rng: &mut Pcg64,
    actor: &str,
    ft_n: u64,
    stats: &mut ActorStats,
) -> Result<()> {
    let name = format!("{actor}-ft{ft_n}");
    repo.create_branch(&name)?;
    repo.checkout(&name)?;
    train_op(repo, rng, actor)?;
    snapshot_op(repo, actor)?;
    repo.checkout("main")?;
    let report = repo.merge(&name, &avg_opts(), actor)?;
    if report.commit.is_some() && !report.fast_forward && !report.already_up_to_date {
        stats.merge_commits += 1;
    }
    stats.finetunes += 1;
    push_with_retry(repo, spec, actor, stats)
}

/// Gc op: a full `gc --prune` on the actor's own clone.
fn gc_op(repo: &Repository, stats: &mut ActorStats) -> Result<()> {
    let report = crate::theta::collect_garbage(repo, true)?;
    stats.gc_runs += 1;
    stats.gc_spared += report.spared as u64;
    Ok(())
}

// ---------------------------------------------------------------------
// the actor loop
// ---------------------------------------------------------------------

/// Derive actor i's RNG seed from the scenario seed (splitmix-style
/// odd-constant mix so adjacent actors decorrelate).
fn actor_seed(seed: u64, i: usize) -> u64 {
    seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One collaborator's whole op phase, run on its own thread against its
/// own clone. Thread-local transfer/scan counters are snapshotted here,
/// inside the thread, before it exits.
fn run_actor(
    i: usize,
    repo: Repository,
    url: String,
    n_ops: usize,
    seed: u64,
) -> Result<ActorStats> {
    let spec = RemoteSpec::parse(&url)?;
    let actor = format!("a{i}");
    let mut rng = Pcg64::new(seed);
    let mut stats = ActorStats::default();
    batch::reset_stats();
    let scans0 = crate::lfs::store::dir_scans();
    let mut branches = 0u64;
    let mut finetunes = 0u64;
    for op_idx in 0..n_ops {
        let roll = rng.below(100);
        let (kind, result): (&str, Result<()>) = if roll < 35 {
            ("train", train_op(&repo, &mut rng, &actor).map(|_| ()))
        } else if roll < 55 {
            ("push", push_with_retry(&repo, &spec, &actor, &mut stats))
        } else if roll < 70 {
            ("pull", pull_op(&repo, &spec, &actor, &mut stats))
        } else if roll < 80 {
            branches += 1;
            ("branch-merge", branch_merge_op(&repo, &mut rng, &actor, branches, &mut stats))
        } else if roll < 85 {
            finetunes += 1;
            ("finetune", finetune_op(&repo, &spec, &mut rng, &actor, finetunes, &mut stats))
        } else if roll < 90 {
            ("clean", clean_op(&repo, &mut rng))
        } else if roll < 95 {
            ("snapshot", snapshot_op(&repo, &actor))
        } else {
            ("gc", gc_op(&repo, &mut stats))
        };
        stats.trace.push(format!("{actor} op{op_idx} {kind}"));
        result.with_context(|| format!("{actor} op {op_idx} ({kind})"))?;
        stats.ops_applied += 1;
    }
    // Flush any staged-but-uncommitted clean-op state so the clone ends
    // its op phase with worktree == HEAD — the quiesce merges then keep
    // the two in lockstep, which the byte-identity proof relies on.
    repo.add(&[MODEL_PATH])?;
    repo.commit("flush", &actor)?;

    let wire = batch::stats();
    stats.round_trips = wire.round_trips();
    stats.wire_bytes = wire.wire_bytes;
    stats.dir_scans = crate::lfs::store::dir_scans() - scans0;
    Ok(stats)
}

// ---------------------------------------------------------------------
// the scenario
// ---------------------------------------------------------------------

/// Run one full scenario: seed hub → concurrent op phase → injected
/// fault phase → quiesce → convergence proof. Never panics on
/// divergence — it dumps the replay trace and reports
/// `converged: false` so callers (tests, the bench gate) decide.
pub fn run_scenario(cfg: &ScenarioConfig) -> Result<ScenarioOutcome> {
    crate::init();
    ensure!(cfg.actors >= 1, "scenario needs at least one actor");
    eprintln!(
        "scenario: {} actors x {} ops, seed {}, {} fault(s) \
         (replay: git-theta bench scenario {} {} {} {})",
        cfg.actors, cfg.ops, cfg.seed, cfg.faults, cfg.actors, cfg.ops, cfg.seed, cfg.faults
    );

    let t0 = Instant::now();
    let tracking = alloc::active();
    let alloc_base = alloc::reset_peak();
    batch::reset_stats();
    let scans0 = crate::lfs::store::dir_scans();

    // The hub: one served root, with a fault proxy in front of it that
    // actor 0's traffic always crosses.
    let td_hub = TempDir::new("scenario-hub")?;
    let server = LfsServer::spawn(td_hub.path())?;
    let proxy = FaultProxy::spawn(&server.url())?;
    let hub_spec = RemoteSpec::parse(&server.url())?;
    let proxy_spec = RemoteSpec::parse(&proxy.url())?;

    // The coordinator seeds the hub with the shared base model.
    let td_coord = TempDir::new("scenario-coord")?;
    let coord = Repository::init(td_coord.path())?;
    Attributes::add_line(
        coord.worktree(),
        "*.safetensors filter=theta diff=theta merge=theta",
    )?;
    save_model(&coord, &base_model(cfg.seed))?;
    coord.add(&[MODEL_PATH, ".thetaattributes"])?;
    coord.commit("base model", "coordinator")?;
    coord.config_set("remote", &server.url())?;
    coord.push_spec(&hub_spec, "main")?;

    // One real clone per actor.
    let mut actor_dirs = Vec::new();
    let mut actor_repos = Vec::new();
    let mut actor_urls = Vec::new();
    for i in 0..cfg.actors {
        let td = TempDir::new("scenario-actor")?;
        let repo = Repository::init(td.path())?;
        let url = if i == 0 { proxy.url() } else { server.url() };
        repo.config_set("remote", &url)?;
        repo.pull_spec(&RemoteSpec::parse(&url)?, "main")?;
        actor_dirs.push(td);
        actor_repos.push(repo);
        actor_urls.push(url);
    }

    // ---- op phase: all actors at once -------------------------------
    let per = cfg.ops / cfg.actors;
    let rem = cfg.ops % cfg.actors;
    let mut handles = Vec::new();
    for (i, repo) in actor_repos.iter().enumerate() {
        let repo = repo.clone();
        let url = actor_urls[i].clone();
        let n_ops = per + usize::from(i < rem);
        let seed = actor_seed(cfg.seed, i);
        handles.push(std::thread::spawn(move || {
            run_actor(i, repo, url, n_ops, seed).map_err(|e| format!("{e:#}"))
        }));
    }
    let mut actor_stats = Vec::new();
    for handle in handles {
        let stats = handle
            .join()
            .map_err(|_| anyhow!("an actor thread panicked"))?
            .map_err(|e| anyhow!(e))?;
        actor_stats.push(stats);
    }

    // ---- fault phase: kill fetches mid-pack, deterministic ----------
    // The coordinator publishes a fresh train step, then actor 0 pulls
    // it through the armed proxy: the first pack fetch must die at the
    // kill offset, and the retry must resume from the partial.
    let mut coordinator = ActorStats::default();
    let mut fired_total = 0u64;
    let mut fetch_retries = 0u64;
    for f in 0..cfg.faults {
        let mut rng = Pcg64::new(cfg.seed ^ 0xFA17_0000 ^ f as u64);
        train_op(&coord, &mut rng, "coordinator")?;
        push_with_retry(&coord, &hub_spec, "coordinator", &mut coordinator)?;

        let a0 = &actor_repos[0];
        let tip = a0.fetch_head_spec(&proxy_spec, "main")?;
        let tree = a0.odb().read_tree(&a0.odb().read_commit(&tip)?.tree)?;
        let local = LfsStore::open(a0.theta_dir());
        let missing: Vec<Oid> = referenced_lfs_oids(a0, &tree)?
            .into_iter()
            .filter(|o| !local.contains(o))
            .collect();
        ensure!(!missing.is_empty(), "fault round {f}: nothing left to fetch");
        let remote = open_transport(&proxy_spec, Some(a0.theta_dir()))?;

        proxy.arm(FaultSpec::kill(Direction::Download, KILL_AT));
        let first = batch::fetch_pack(remote.as_ref(), &local, &missing);
        ensure!(first.is_err(), "fault round {f}: armed kill did not interrupt the fetch");
        ensure!(proxy.fired() == fired_total + 1, "fault round {f}: kill never fired");
        fired_total = proxy.fired();
        fetch_retries += 1;

        let retry = batch::fetch_pack(remote.as_ref(), &local, &missing)
            .with_context(|| format!("fault round {f}: retry after mid-pack kill"))?;
        ensure!(retry.unavailable == 0, "fault round {f}: resumed fetch left objects behind");
        ensure!(
            retry.resumed_bytes >= KILL_AT,
            "fault round {f}: retry re-sent bytes the partial already held"
        );
        merge_tip(a0, tip, "a0", &mut coordinator)?;
    }
    proxy.disarm();

    // ---- quiesce: fetch/merge/push rounds to a fixpoint -------------
    let mut fleet: Vec<(String, &Repository, String)> =
        vec![("coordinator".to_string(), &coord, server.url())];
    for (i, repo) in actor_repos.iter().enumerate() {
        fleet.push((format!("a{i}"), repo, actor_urls[i].clone()));
    }
    let mut quiesce_rounds = 0u64;
    loop {
        quiesce_rounds += 1;
        ensure!(
            quiesce_rounds <= 4 + 2 * fleet.len() as u64,
            "quiesce did not reach a fixpoint (seed {})",
            cfg.seed
        );
        for (name, repo, url) in &fleet {
            let spec = RemoteSpec::parse(url)?;
            let tip = repo.fetch_head_spec(&spec, "main")?;
            merge_tip(repo, tip, name, &mut coordinator)?;
            push_with_retry(repo, &spec, name, &mut coordinator)?;
        }
        let hub_tip = coord.fetch_head_spec(&hub_spec, "main")?;
        let settled = {
            let mut ok = true;
            for (_, repo, _) in &fleet {
                if repo.head_commit()? != Some(hub_tip) {
                    ok = false;
                    break;
                }
            }
            ok
        };
        if settled {
            break;
        }
    }

    // ---- convergence proof ------------------------------------------
    let mut converged = true;
    let reference = std::fs::read(coord.worktree().join(MODEL_PATH))
        .context("reading the coordinator's checked-out model")?;
    for (i, repo) in actor_repos.iter().enumerate() {
        let bytes = std::fs::read(repo.worktree().join(MODEL_PATH))
            .with_context(|| format!("reading actor a{i}'s checked-out model"))?;
        if bytes != reference {
            eprintln!("scenario DIVERGED: actor a{i}'s checkout differs from the coordinator's");
            converged = false;
        }
    }
    // A fresh clone straight from the hub must reproduce the bytes.
    let td_verify = TempDir::new("scenario-verify")?;
    let verify = Repository::init(td_verify.path())?;
    verify.config_set("remote", &server.url())?;
    verify.pull_spec(&hub_spec, "main")?;
    if std::fs::read(td_verify.path().join(MODEL_PATH))? != reference {
        eprintln!("scenario DIVERGED: a fresh clone of the hub differs from the fleet");
        converged = false;
    }
    // Full hub-store verify pass: every object must re-hash to its id.
    let hub_store = LfsStore::at(&td_hub.path().join("lfs/objects"));
    let mut store_objects_verified = 0usize;
    for oid in hub_store.list()? {
        let bytes = hub_store.get(&oid)?;
        if Oid::of_bytes(&bytes) != oid {
            eprintln!("scenario DIVERGED: hub store object {oid} fails verification");
            converged = false;
        } else {
            store_objects_verified += 1;
        }
    }

    let traces: Vec<Vec<String>> = actor_stats.iter().map(|s| s.trace.clone()).collect();
    if !converged {
        let path = std::path::PathBuf::from(format!("scenario_trace_{}.txt", cfg.seed));
        let mut dump = String::new();
        for s in &actor_stats {
            for line in &s.trace {
                dump.push_str(line);
                dump.push('\n');
            }
        }
        let _ = std::fs::write(&path, dump);
        eprintln!(
            "replay with: git-theta bench scenario {} {} {} {} (op trace: {})",
            cfg.actors,
            cfg.ops,
            cfg.seed,
            cfg.faults,
            path.display()
        );
    }

    // ---- aggregate --------------------------------------------------
    let mut out = ScenarioOutcome {
        actors: cfg.actors,
        ops_requested: cfg.ops,
        ops_applied: 0,
        converged,
        faults_fired: fired_total,
        fetch_retries,
        pushes: coordinator.pushes,
        push_retries: coordinator.push_retries,
        merge_commits: coordinator.merge_commits,
        gc_runs: coordinator.gc_runs,
        gc_spared: coordinator.gc_spared,
        finetunes: coordinator.finetunes,
        quiesce_rounds,
        round_trips: 0,
        wire_bytes: 0,
        dir_scans: 0,
        store_objects_verified,
        peak_heap_bytes: 0,
        scenario_secs: 0.0,
        traces,
    };
    for s in &actor_stats {
        out.ops_applied += s.ops_applied;
        out.pushes += s.pushes;
        out.push_retries += s.push_retries;
        out.merge_commits += s.merge_commits;
        out.gc_runs += s.gc_runs;
        out.gc_spared += s.gc_spared;
        out.finetunes += s.finetunes;
        out.round_trips += s.round_trips;
        out.wire_bytes += s.wire_bytes;
        out.dir_scans += s.dir_scans;
    }
    // The coordinator thread's own wire/scan counters (seeding, fault
    // phase, quiesce all ran here).
    let wire = batch::stats();
    out.round_trips += wire.round_trips();
    out.wire_bytes += wire.wire_bytes;
    out.dir_scans += crate::lfs::store::dir_scans() - scans0;
    out.peak_heap_bytes = if tracking {
        alloc::peak_bytes().saturating_sub(alloc_base) as u64
    } else {
        0
    };
    out.scenario_secs = t0.elapsed().as_secs_f64();
    Ok(out)
}

// ---------------------------------------------------------------------
// rendering + CLI
// ---------------------------------------------------------------------

/// Human-readable summary of a scenario run.
pub fn render_outcome(out: &ScenarioOutcome) -> String {
    let peak = if out.peak_heap_bytes == 0 {
        "n/a".to_string()
    } else {
        humansize::bytes(out.peak_heap_bytes)
    };
    format!(
        "scenario: {} actors, {}/{} ops applied — {}\n\
         quiesced in {} round(s); hub store verified ({} objects)\n\
         pushes {} (+{} contention retries), merge commits {}, fine-tunes {}, \
         gc runs {} (spared {})\n\
         faults fired {} (fetch retries {}); wire {} over {} round trips; \
         {} dir scans; peak heap {}; {}\n",
        out.actors,
        out.ops_applied,
        out.ops_requested,
        if out.converged { "CONVERGED" } else { "DIVERGED" },
        out.quiesce_rounds,
        out.store_objects_verified,
        out.pushes,
        out.push_retries,
        out.merge_commits,
        out.finetunes,
        out.gc_runs,
        out.gc_spared,
        out.faults_fired,
        out.fetch_retries,
        humansize::bytes(out.wire_bytes),
        out.round_trips,
        out.dir_scans,
        peak,
        humansize::duration(out.scenario_secs),
    )
}

/// Encode the run as the `BENCH_scenario.json` payload for the gate.
pub fn outcome_to_json(cfg: &ScenarioConfig, out: &ScenarioOutcome) -> Json {
    let mut root = JsonObj::new();
    root.insert("bench", "scenario");
    root.insert("actors", out.actors);
    root.insert("ops", out.ops_requested);
    root.insert("seed", cfg.seed);
    root.insert("converged", u64::from(out.converged));
    root.insert("ops_applied", out.ops_applied);
    root.insert("faults_fired", out.faults_fired);
    root.insert("fetch_retries", out.fetch_retries);
    root.insert("pushes", out.pushes);
    root.insert("push_retries", out.push_retries);
    root.insert("merge_commits", out.merge_commits);
    root.insert("gc_runs", out.gc_runs);
    root.insert("gc_spared", out.gc_spared);
    root.insert("finetunes", out.finetunes);
    root.insert("quiesce_rounds", out.quiesce_rounds);
    root.insert("round_trips", out.round_trips);
    root.insert("wire_bytes", out.wire_bytes);
    root.insert("dir_scans", out.dir_scans);
    root.insert("store_objects_verified", out.store_objects_verified);
    root.insert("peak_heap_bytes", out.peak_heap_bytes);
    root.insert("scenario_secs", Json::Num(out.scenario_secs));
    Json::Obj(root)
}

/// `git-theta bench scenario [actors] [ops] [seed] [faults]`.
pub fn run_scenario_cli(args: &[String]) -> Result<()> {
    let cfg = ScenarioConfig {
        actors: args.first().and_then(|s| s.parse().ok()).unwrap_or(4),
        ops: args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40),
        seed: args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0xCAFE_BABE),
        faults: args.get(3).and_then(|s| s.parse().ok()).unwrap_or(1),
    };
    let out = run_scenario(&cfg)?;
    print!("{}", render_outcome(&out));
    let path = write_bench_json("scenario", outcome_to_json(&cfg, &out))?;
    println!("wrote {}", path.display());
    ensure!(out.converged, "scenario seed {} did not converge", cfg.seed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actor_seeds_decorrelate() {
        let a = actor_seed(1, 0);
        let b = actor_seed(1, 1);
        let c = actor_seed(2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        // And they are pure functions of (seed, index).
        assert_eq!(a, actor_seed(1, 0));
    }

    #[test]
    fn tiny_scenario_converges_and_counts() {
        let cfg = ScenarioConfig {
            actors: 2,
            ops: 8,
            seed: 11,
            faults: 1,
        };
        let out = run_scenario(&cfg).unwrap();
        assert!(out.converged, "tiny scenario diverged");
        assert_eq!(out.ops_applied, 8);
        assert_eq!(out.faults_fired, 1);
        assert_eq!(out.fetch_retries, 1);
        assert!(out.store_objects_verified > 0);
        assert!(out.round_trips > 0);
        assert!(out.wire_bytes > 0);
        assert_eq!(out.traces.len(), 2);
        assert_eq!(out.traces.iter().map(|t| t.len()).sum::<usize>(), 8);
    }
}
