//! Transfer-engine ablation: per-object vs packed vs http transport.
//!
//! Builds a synthetic model store — N parameter-group objects of
//! bf16-valued f32 data (the Table 1 compressibility profile) — and
//! moves it through the transfer engines in both directions, reporting
//! round trips (negotiations + packs), wire bytes, and wall-clock.
//! Over a real network the round-trip column is the one that matters:
//! per-object transfer pays one copy request per group, the pack
//! engine pays one negotiation plus one pack per model — identical
//! logical counts whether the channel is a directory or the HTTP
//! remote.
//!
//! The `+resume` lever samples an injected fault: a
//! [`FaultProxy`](crate::lfs::faults::FaultProxy) kills the pack
//! stream halfway, and the retry's byte-range resume is measured
//! against a from-scratch transfer (`BENCH_transfer.json` carries the
//! ratio for the CI regression gate).
//!
//! The `+delta` lever measures the chain-aware wire protocol in both
//! directions. Push: a fine-tune whose base model the remote already
//! holds, flat (protocol 1, every object ships whole) vs chain-aware
//! (the client advertises the chains, the server answers with held
//! depths, and the pack ships delta records against the remote bases).
//! Fetch: a clone that holds the shared base pulls the fine-tune, flat
//! vs chain-aware (the client advertises the chains it holds, the
//! server plans deltas against the client's bases — consulting its
//! (base, target) plan cache — and the clone reconstructs locally). The
//! wire-bytes ratios, the round-trip counts, and the plan-cache hit
//! count are locked in `bench_baseline.json`.

use super::time_once;
use crate::gitcore::object::Oid;
use crate::lfs::batch::Prefetcher;
use crate::lfs::faults::{Direction, FaultProxy, FaultSpec};
use crate::lfs::{batch, transport, HttpRemote, LfsRemote, LfsServer, LfsStore};
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use crate::util::{alloc, humansize};
use anyhow::{ensure, Result};

/// Measurements for one engine: upload + download legs.
#[derive(Debug, Clone)]
pub struct TransferRun {
    /// Engine name ("per-object", "packed", or "http").
    pub mode: &'static str,
    /// Wall-clock seconds for the upload leg.
    pub upload_secs: f64,
    /// Thread-local transfer counters captured after the upload leg.
    pub up: batch::TransferStats,
    /// Wall-clock seconds for the download leg (fresh clone).
    pub download_secs: f64,
    /// Counters captured after the download leg.
    pub down: batch::TransferStats,
}

/// One injected-fault resume measurement (the `+resume` lever).
#[derive(Debug, Clone, Copy)]
pub struct ResumeSample {
    /// Full pack size in bytes.
    pub pack_bytes: u64,
    /// Where the fault proxy cut the first attempt.
    pub killed_after: u64,
    /// Pack bytes the successful retry actually sent.
    pub retry_wire_bytes: u64,
    /// Pack bytes the retry skipped thanks to byte-range resume.
    pub retry_resumed_bytes: u64,
}

impl ResumeSample {
    /// Fraction of the pack the retry re-sent (1.0 = no resume).
    pub fn retry_fraction(&self) -> f64 {
        self.retry_wire_bytes as f64 / (self.pack_bytes as f64).max(1.0)
    }
}

/// One streaming-pipeline measurement (the `+stream` lever): peak heap
/// during an http pack round trip, and TCP connects vs requests.
#[derive(Debug, Clone, Copy)]
pub struct StreamSample {
    /// Bytes of the single pack that moved each way.
    pub pack_bytes: u64,
    /// Largest single object in the pack (the streaming memory unit).
    pub largest_object: u64,
    /// Peak transient heap during push + fetch (client *and* in-process
    /// server side). 0 when the running binary has no
    /// [`TrackingAlloc`](crate::util::alloc::TrackingAlloc) installed.
    pub peak_heap_bytes: u64,
    /// `peak_heap_bytes / pack_bytes` — the locked bound: streaming
    /// keeps this well under 1 however large the pack, where the old
    /// RAM-materialized path needed several multiples of the pack.
    pub peak_ratio: f64,
    /// TCP connections the client opened for the whole round trip.
    pub http_connects: u64,
    /// Logical wire requests made (negotiations + pack transfers).
    pub requests: u64,
}

/// Push + fetch one model through a real localhost http server with a
/// pinned 2-thread engine, measuring peak heap (when a `TrackingAlloc`
/// is installed — the `git-theta` CLI installs one) and connection
/// reuse. Threads are pinned so the streaming window — and therefore
/// the locked peak-heap bound — does not scale with the host's cores.
pub fn run_stream_sample(groups: usize, elems: usize) -> Result<StreamSample> {
    let (_td_local, local, oids) = seeded_local(groups, elems)?;
    let largest_object = oids
        .iter()
        .filter_map(|o| local.size_of(o))
        .max()
        .unwrap_or(0);
    let td_root = TempDir::new("xfer-stream-root")?;
    let server = LfsServer::spawn(td_root.path())?;
    let td_staging = TempDir::new("xfer-stream-staging")?;
    let remote = HttpRemote::open(&server.url(), Some(td_staging.path()))?;
    let engine = Prefetcher {
        threads: 2,
        ..Prefetcher::default()
    };

    batch::reset_stats();
    let tracking = alloc::active();
    let base = alloc::reset_peak();
    let up = engine.push(&local, &remote, &oids)?;
    let td_clone = TempDir::new("xfer-stream-clone")?;
    let clone_store = LfsStore::open(td_clone.path());
    let down = engine.fetch(&remote, &clone_store, &oids)?;
    let peak_heap_bytes = if tracking {
        alloc::peak_bytes().saturating_sub(base) as u64
    } else {
        0
    };
    ensure!(up.objects == groups, "stream sample upload incomplete");
    ensure!(down.objects == groups, "stream sample download incomplete");
    ensure!(batch::stats().packs == 2, "stream sample must move exactly one pack each way");
    let pack_bytes = up.packed_bytes;
    Ok(StreamSample {
        pack_bytes,
        largest_object,
        peak_heap_bytes,
        peak_ratio: peak_heap_bytes as f64 / (pack_bytes as f64).max(1.0),
        http_connects: remote.connections_opened(),
        requests: batch::stats().round_trips(),
    })
}

/// The `+delta` lever: wire cost of pushing a fine-tune over a base
/// the remote already holds, flat vs chain-aware.
#[derive(Debug, Clone, Copy)]
pub struct DeltaSample {
    /// Wire bytes of the flat (protocol-1) push of the fine-tune.
    pub full_wire_bytes: u64,
    /// Wire bytes of the chain-aware push of the same objects.
    pub delta_wire_bytes: u64,
    /// `delta_wire_bytes / full_wire_bytes` — the locked headline
    /// (< 0.5 is the acceptance bar for a tail-quarter fine-tune).
    pub ratio: f64,
    /// Logical round trips of the chain-aware push (1 negotiation +
    /// 1 pack — chains ride the existing batch round trip).
    pub round_trips: u64,
    /// Objects that shipped as delta records rather than full bodies.
    pub delta_objects: u64,
}

/// The fetch mirror of [`DeltaSample`]: wire cost of a clone that
/// holds the shared base pulling the fine-tune, flat vs chain-aware.
#[derive(Debug, Clone, Copy)]
pub struct FetchDeltaSample {
    /// Wire bytes of the flat (protocol-1) fetch of the fine-tune.
    pub full_wire_bytes: u64,
    /// Wire bytes of the chain-aware fetch of the same objects.
    pub delta_wire_bytes: u64,
    /// `delta_wire_bytes / full_wire_bytes` — the locked headline
    /// (≤ 0.5 is the acceptance bar for a tail-quarter fine-tune).
    pub ratio: f64,
    /// Logical round trips of the chain-aware fetch (1 negotiation +
    /// 1 pack — same budget as the flat path).
    pub round_trips: u64,
    /// Objects that arrived as delta records rather than full bodies.
    pub delta_objects: u64,
    /// Server plan-cache hits after a second clone fetched a superset
    /// want: every (base, fine-tune) encode is answered from cache.
    pub plan_cache_hits: u64,
}

/// Base + fine-tune payload pair shared by both `+delta` directions:
/// the fine-tune keeps the leading 3/4 of every group and re-trains
/// the tail quarter (seed 43) — the shape of a parameter-efficient
/// update.
fn fine_tune_payloads(groups: usize, elems: usize) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let bases = synth_group_payloads(groups, elems, 42);
    let fresh = synth_group_payloads(groups, elems, 43);
    let tuned = bases
        .iter()
        .zip(&fresh)
        .map(|(b, f)| {
            let keep = b.len() - b.len() / 4;
            let mut t = b[..keep].to_vec();
            t.extend_from_slice(&f[keep..]);
            t
        })
        .collect();
    (bases, tuned)
}

/// One two-entry chain advert per group: "the base is depth 1 of this
/// chain; the fine-tune is its suffix".
fn two_entry_chains(
    base_oids: &[Oid],
    tuned_oids: &[Oid],
) -> Vec<Vec<transport::ChainEntryAdvert>> {
    base_oids
        .iter()
        .zip(tuned_oids)
        .map(|(b, t)| {
            vec![
                transport::ChainEntryAdvert {
                    key: *b,
                    oids: vec![*b],
                },
                transport::ChainEntryAdvert {
                    key: *t,
                    oids: vec![*t],
                },
            ]
        })
        .collect()
}

/// Push a fine-tune whose base model is already on the remote, once
/// over the flat protocol and once chain-aware, and compare wire
/// bytes. Both pushes cross a real localhost http server and the
/// delta push's reconstructed objects are byte-verified against the
/// sender's.
pub fn run_delta_sample(groups: usize, elems: usize) -> Result<DeltaSample> {
    use crate::lfs::transport::ChainAdvert;
    let (bases, tuned) = fine_tune_payloads(groups, elems);

    let td_local = TempDir::new("xfer-delta-local")?;
    let local = LfsStore::open(td_local.path());
    let base_oids: Vec<Oid> = bases
        .iter()
        .map(|p| Ok(local.put(p)?.0))
        .collect::<Result<_>>()?;
    let tuned_oids: Vec<Oid> = tuned
        .iter()
        .map(|p| Ok(local.put(p)?.0))
        .collect::<Result<_>>()?;

    // Two identically seeded servers: each already holds the base.
    let spawn_seeded = |tag: &str| -> Result<(TempDir, LfsServer, HttpRemote, TempDir)> {
        let td_root = TempDir::new(&format!("xfer-delta-{tag}"))?;
        let server = LfsServer::spawn(td_root.path())?;
        let td_staging = TempDir::new(&format!("xfer-delta-{tag}-staging"))?;
        let remote = HttpRemote::open(&server.url(), Some(td_staging.path()))?;
        batch::push_pack(&local, &remote, &base_oids)?;
        Ok((td_root, server, remote, td_staging))
    };

    // Flat push: the fine-tune ships every object whole.
    let (_root_full, server_full, remote_full, _stage_full) = spawn_seeded("full")?;
    batch::reset_stats();
    let full = batch::push_pack(&local, &remote_full, &tuned_oids)?;
    ensure!(full.objects == groups, "flat delta-sample push incomplete");
    drop(server_full);

    // Chain-aware push of the same objects.
    let adv = ChainAdvert {
        chains: two_entry_chains(&base_oids, &tuned_oids),
        want: tuned_oids.clone(),
    };
    let (root_delta, server_delta, remote_delta, _stage_delta) = spawn_seeded("delta")?;
    batch::reset_stats();
    let deltaed = Prefetcher::default().push_with_chains(&local, &remote_delta, &adv)?;
    let stats = batch::stats();
    ensure!(deltaed.objects == groups, "chain-aware delta-sample push incomplete");
    // The server must have reconstructed byte-identical objects from
    // the delta records.
    let server_store = LfsStore::at(&root_delta.join("lfs/objects"));
    for (oid, payload) in tuned_oids.iter().zip(&tuned) {
        ensure!(
            &server_store.get(oid)? == payload,
            "delta push produced a corrupt object on the receiver"
        );
    }
    drop(server_delta);

    Ok(DeltaSample {
        full_wire_bytes: full.wire_bytes,
        delta_wire_bytes: deltaed.wire_bytes,
        ratio: deltaed.wire_bytes as f64 / (full.wire_bytes as f64).max(1.0),
        round_trips: stats.round_trips(),
        delta_objects: stats.delta_objects,
    })
}

/// Fetch a fine-tune into clones that already hold the shared base,
/// once flat and once chain-aware, against one http server holding
/// both versions (the fresh-clone-with-base shape: `git-theta clone` a
/// base checkpoint, then `fetch` a fine-tune branch). A third clone
/// repeats the chain-aware fetch with a superset want so the server's
/// advert memo misses and its (base, target) plan cache answers every
/// re-planned encode.
pub fn run_fetch_delta_sample(groups: usize, elems: usize) -> Result<FetchDeltaSample> {
    use crate::lfs::transport::ChainAdvert;
    let (bases, tuned) = fine_tune_payloads(groups, elems);

    // One server holding base + fine-tune: the upstream everyone pulls.
    let td_seed = TempDir::new("xfer-fdelta-seed")?;
    let seed = LfsStore::open(td_seed.path());
    let base_oids: Vec<Oid> = bases
        .iter()
        .map(|p| Ok(seed.put(p)?.0))
        .collect::<Result<_>>()?;
    let tuned_oids: Vec<Oid> = tuned
        .iter()
        .map(|p| Ok(seed.put(p)?.0))
        .collect::<Result<_>>()?;
    let td_root = TempDir::new("xfer-fdelta-root")?;
    let server = LfsServer::spawn(td_root.path())?;
    let td_up = TempDir::new("xfer-fdelta-up")?;
    let upstream = HttpRemote::open(&server.url(), Some(td_up.path()))?;
    let mut all = base_oids.clone();
    all.extend(&tuned_oids);
    batch::push_pack(&seed, &upstream, &all)?;

    // Each clone starts with the base materialized locally.
    let clone_with_base = |tag: &str| -> Result<(TempDir, LfsStore, HttpRemote, TempDir)> {
        let td = TempDir::new(&format!("xfer-fdelta-{tag}"))?;
        let store = LfsStore::open(td.path());
        for p in &bases {
            store.put(p)?;
        }
        let td_staging = TempDir::new(&format!("xfer-fdelta-{tag}-staging"))?;
        let remote = HttpRemote::open(&server.url(), Some(td_staging.path()))?;
        Ok((td, store, remote, td_staging))
    };

    // Flat fetch: the fine-tune arrives whole.
    let (_td_flat, flat_store, flat_remote, _stage_flat) = clone_with_base("flat")?;
    batch::reset_stats();
    let flat = batch::fetch_pack(&flat_remote, &flat_store, &tuned_oids)?;
    ensure!(flat.objects == groups, "flat fetch-delta sample incomplete");

    // Chain-aware fetch of the same objects into a second clone.
    let adv = ChainAdvert {
        chains: two_entry_chains(&base_oids, &tuned_oids),
        want: tuned_oids.clone(),
    };
    let (_td_chain, chain_store, chain_remote, _stage_chain) = clone_with_base("chain")?;
    batch::reset_stats();
    let deltaed = Prefetcher::default().fetch_with_chains(&chain_remote, &chain_store, &adv)?;
    let stats = batch::stats();
    ensure!(deltaed.objects == groups, "chain-aware fetch-delta sample incomplete");
    // The clone must have reconstructed byte-identical objects from the
    // delta records against its local bases.
    for (oid, payload) in tuned_oids.iter().zip(&tuned) {
        ensure!(
            &chain_store.get(oid)? == payload,
            "chain-aware fetch produced a corrupt object on the client"
        );
    }

    // Third clone, superset want (one extra fresh object): the advert
    // memo misses, the planner re-runs, and every (base, fine-tune)
    // encode must come back from the plan cache instead of re-chunking.
    let extra_payload = synth_group_payloads(1, elems, 44).remove(0);
    let extra = seed.put(&extra_payload)?.0;
    batch::push_pack(&seed, &upstream, &[extra])?;
    let (_td_cache, cache_store, cache_remote, _stage_cache) = clone_with_base("cache")?;
    let mut superset = tuned_oids.clone();
    superset.push(extra);
    let cache_adv = ChainAdvert {
        chains: two_entry_chains(&base_oids, &tuned_oids),
        want: superset,
    };
    let repeat = Prefetcher::default().fetch_with_chains(&cache_remote, &cache_store, &cache_adv)?;
    ensure!(repeat.objects == groups + 1, "cache fetch-delta sample incomplete");
    let metrics = server.metrics();
    ensure!(
        metrics.plan_cache_hits >= groups as u64,
        "repeat fetch answered {} plan-cache hits, expected >= {groups}",
        metrics.plan_cache_hits
    );
    drop(server);

    Ok(FetchDeltaSample {
        full_wire_bytes: flat.wire_bytes,
        delta_wire_bytes: deltaed.wire_bytes,
        ratio: deltaed.wire_bytes as f64 / (flat.wire_bytes as f64).max(1.0),
        round_trips: stats.round_trips(),
        delta_objects: stats.delta_objects,
        plan_cache_hits: metrics.plan_cache_hits,
    })
}

/// Synthesize `groups` parameter-group payloads of `elems` f32s each,
/// holding bf16-precision values (low mantissa bytes zero — the
/// compressibility profile of real distributed checkpoints).
pub fn synth_group_payloads(groups: usize, elems: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg64::new(seed);
    (0..groups)
        .map(|_| {
            let mut buf = Vec::with_capacity(elems * 4);
            for _ in 0..elems {
                let v = (rng.next_f32() - 0.5) * 2.0;
                let bf16ish = crate::tensor::bf16_to_f32(crate::tensor::f32_to_bf16(v));
                buf.extend_from_slice(&bf16ish.to_le_bytes());
            }
            buf
        })
        .collect()
}

fn seeded_local(groups: usize, elems: usize) -> Result<(TempDir, LfsStore, Vec<Oid>)> {
    let td = TempDir::new("xfer-local")?;
    let local = LfsStore::open(td.path());
    let oids: Vec<Oid> = synth_group_payloads(groups, elems, 42)
        .iter()
        .map(|p| Ok(local.put(p)?.0))
        .collect::<Result<_>>()?;
    Ok((td, local, oids))
}

/// Run all engines over the same `groups`×`elems` synthetic model.
/// Row order is stable: per-object, packed, http.
pub fn run_compare(groups: usize, elems: usize) -> Result<Vec<TransferRun>> {
    let (_td_local, local, oids) = seeded_local(groups, elems)?;
    let mut runs = Vec::new();

    for mode in ["per-object", "packed", "http"] {
        let td_remote = TempDir::new("xfer-remote")?;
        let td_staging = TempDir::new("xfer-staging")?;
        // The http row goes through a real server over localhost TCP;
        // the dir rows touch the remote directory directly. The server
        // handle must outlive the legs (it stops on drop).
        let mut server = None;
        let remote: Box<dyn crate::lfs::RemoteTransport> = if mode == "http" {
            let srv = LfsServer::spawn(td_remote.path())?;
            let r = Box::new(HttpRemote::open(&srv.url(), Some(td_staging.path()))?);
            server = Some(srv);
            r
        } else {
            Box::new(LfsRemote::open(td_remote.path()))
        };

        batch::reset_stats();
        let (upload_secs, _) = time_once(|| match mode {
            "per-object" => {
                transport::upload_per_object(&local, remote.as_ref(), &oids).map(|_| ())
            }
            _ => batch::push_pack(&local, remote.as_ref(), &oids).map(|_| ()),
        })?;
        let up = batch::stats();

        let td_clone = TempDir::new("xfer-clone")?;
        let clone_store = LfsStore::open(td_clone.path());
        batch::reset_stats();
        let (download_secs, _) = time_once(|| match mode {
            "per-object" => {
                transport::download_per_object(remote.as_ref(), &clone_store, &oids).map(|_| ())
            }
            _ => batch::fetch_pack(remote.as_ref(), &clone_store, &oids).map(|_| ()),
        })?;
        let down = batch::stats();
        drop(server);

        runs.push(TransferRun {
            mode,
            upload_secs,
            up,
            download_secs,
            down,
        });
    }
    Ok(runs)
}

/// The `+resume` lever: push the model to an http remote, then fetch
/// it through a fault proxy that kills the pack stream halfway. The
/// first attempt must fail; the retry resumes from the persisted
/// partial and is measured against the full pack size.
pub fn run_resume_sample(groups: usize, elems: usize) -> Result<ResumeSample> {
    let (_td_local, local, oids) = seeded_local(groups, elems)?;
    let td_root = TempDir::new("xfer-resume-root")?;
    let server = LfsServer::spawn(td_root.path())?;

    // Seed the server through a clean push.
    let td_up_staging = TempDir::new("xfer-resume-up")?;
    let direct = HttpRemote::open(&server.url(), Some(td_up_staging.path()))?;
    batch::push_pack(&local, &direct, &oids)?;

    // Learn the pack size with an unfaulted fetch into a scratch store.
    let td_scratch = TempDir::new("xfer-resume-scratch")?;
    let scratch = LfsStore::open(td_scratch.path());
    batch::reset_stats();
    let baseline = batch::fetch_pack(&direct, &scratch, &oids)?;
    let pack_bytes = baseline.packed_bytes;
    ensure!(pack_bytes > 2, "resume sample needs a non-trivial pack");
    let killed_after = pack_bytes / 2;

    // Faulted fetch: attempt 1 dies at killed_after, the retry resumes.
    let proxy = FaultProxy::spawn(&server.url())?;
    let td_staging = TempDir::new("xfer-resume-staging")?;
    let remote = HttpRemote::open(&proxy.url(), Some(td_staging.path()))?;
    let td_store = TempDir::new("xfer-resume-store")?;
    let store = LfsStore::open(td_store.path());

    proxy.arm(FaultSpec::kill(Direction::Download, killed_after));
    let first = batch::fetch_pack(&remote, &store, &oids);
    ensure!(first.is_err(), "fault proxy must interrupt the first fetch");
    ensure!(proxy.fired() == 1, "fault did not fire");

    batch::reset_stats();
    let retry = batch::fetch_pack(&remote, &store, &oids)?;
    ensure!(retry.unavailable == 0, "resumed fetch left objects behind");
    Ok(ResumeSample {
        pack_bytes,
        killed_after,
        retry_wire_bytes: retry.wire_bytes,
        retry_resumed_bytes: retry.resumed_bytes,
    })
}

/// Render the comparison as a paper-style table.
pub fn render_runs(groups: usize, elems: usize, runs: &[TransferRun]) -> String {
    let rows: Vec<Vec<String>> = runs
        .iter()
        .flat_map(|r| {
            vec![
                vec![
                    r.mode.to_string(),
                    "upload".into(),
                    r.up.round_trips().to_string(),
                    r.up.packs.to_string(),
                    humansize::bytes(r.up.packed_bytes),
                    humansize::bytes(r.up.raw_bytes),
                    humansize::duration(r.upload_secs),
                ],
                vec![
                    r.mode.to_string(),
                    "download".into(),
                    r.down.round_trips().to_string(),
                    r.down.packs.to_string(),
                    humansize::bytes(r.down.packed_bytes),
                    humansize::bytes(r.down.raw_bytes),
                    humansize::duration(r.download_secs),
                ],
            ]
        })
        .collect();
    format!(
        "Transfer ablation: {groups} groups x {elems} f32 elems\n{}",
        super::render_table(
            &["Engine", "Leg", "Round trips", "Packs", "Wire", "Raw", "Time"],
            &rows,
        )
    )
}

/// Render the `+stream` bounded-memory sample.
pub fn render_stream(sample: &StreamSample) -> String {
    let peak = if sample.peak_heap_bytes == 0 {
        "n/a (no tracking allocator)".to_string()
    } else {
        format!(
            "{} (ratio {:.2} of the pack)",
            humansize::bytes(sample.peak_heap_bytes),
            sample.peak_ratio
        )
    };
    format!(
        "+stream (bounded memory): pack {}, largest object {}, peak heap {}, \
         {} requests over {} TCP connection(s)\n",
        humansize::bytes(sample.pack_bytes),
        humansize::bytes(sample.largest_object),
        peak,
        sample.requests,
        sample.http_connects,
    )
}

/// Render the `+delta` chain-aware ablation row (push direction).
pub fn render_delta(groups: usize, elems: usize, sample: &DeltaSample) -> String {
    format!(
        "+delta (fine-tune over shared base, {groups}x{elems}): full push {}, chain-aware \
         push {} (ratio {:.2}), {} round trips, {} delta object(s)\n",
        humansize::bytes(sample.full_wire_bytes),
        humansize::bytes(sample.delta_wire_bytes),
        sample.ratio,
        sample.round_trips,
        sample.delta_objects,
    )
}

/// Render the `+delta` fetch-direction ablation row.
pub fn render_fetch_delta(groups: usize, elems: usize, sample: &FetchDeltaSample) -> String {
    format!(
        "+delta fetch (clone holding base, {groups}x{elems}): flat fetch {}, chain-aware \
         fetch {} (ratio {:.2}), {} round trips, {} delta object(s), {} plan-cache hit(s)\n",
        humansize::bytes(sample.full_wire_bytes),
        humansize::bytes(sample.delta_wire_bytes),
        sample.ratio,
        sample.round_trips,
        sample.delta_objects,
        sample.plan_cache_hits,
    )
}

/// Render the `+resume` fault sample.
pub fn render_resume(sample: &ResumeSample) -> String {
    format!(
        "+resume (injected fault): pack {}, killed after {}, retry sent {} (resumed {}, \
         {:.0}% saved)\n",
        humansize::bytes(sample.pack_bytes),
        humansize::bytes(sample.killed_after),
        humansize::bytes(sample.retry_wire_bytes),
        humansize::bytes(sample.retry_resumed_bytes),
        100.0 * (1.0 - sample.retry_fraction()),
    )
}

/// Encode both `+delta` samples (with the configuration that produced
/// them) as the `"delta"` object of `BENCH_transfer.json`. Push keys
/// are unprefixed (the original schema); fetch keys carry a `fetch_`
/// prefix so both directions' gates live under one object.
pub fn delta_to_json(
    groups: usize,
    elems: usize,
    sample: &DeltaSample,
    fetch: &FetchDeltaSample,
) -> Json {
    let mut d = JsonObj::new();
    d.insert("groups", groups);
    d.insert("elems", elems);
    d.insert("full_wire_bytes", sample.full_wire_bytes);
    d.insert("delta_wire_bytes", sample.delta_wire_bytes);
    d.insert("ratio", Json::Num(sample.ratio));
    d.insert("round_trips", sample.round_trips);
    d.insert("delta_objects", sample.delta_objects);
    d.insert("fetch_full_wire_bytes", fetch.full_wire_bytes);
    d.insert("fetch_delta_wire_bytes", fetch.delta_wire_bytes);
    d.insert("fetch_ratio", Json::Num(fetch.ratio));
    d.insert("fetch_round_trips", fetch.round_trips);
    d.insert("fetch_delta_objects", fetch.delta_objects);
    d.insert("plan_cache_hits", fetch.plan_cache_hits);
    Json::Obj(d)
}

/// Encode the ablation as the machine-readable `BENCH_transfer.json`
/// payload (perf trajectory tracking + the CI regression gate).
pub fn runs_to_json(
    groups: usize,
    elems: usize,
    runs: &[TransferRun],
    resume: &ResumeSample,
    stream: &StreamSample,
) -> Json {
    let mut root = JsonObj::new();
    root.insert("bench", "transfer");
    root.insert("groups", groups);
    root.insert("elems", elems);
    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            let mut o = JsonObj::new();
            o.insert("mode", r.mode);
            o.insert("up_round_trips", r.up.round_trips());
            o.insert("up_packs", r.up.packs);
            o.insert("up_wire_bytes", r.up.wire_bytes);
            o.insert("up_raw_bytes", r.up.raw_bytes);
            o.insert("upload_secs", Json::Num(r.upload_secs));
            o.insert("down_round_trips", r.down.round_trips());
            o.insert("down_packs", r.down.packs);
            o.insert("down_wire_bytes", r.down.wire_bytes);
            o.insert("download_secs", Json::Num(r.download_secs));
            Json::Obj(o)
        })
        .collect();
    root.insert("runs", Json::Arr(rows));
    let mut res = JsonObj::new();
    res.insert("pack_bytes", resume.pack_bytes);
    res.insert("killed_after", resume.killed_after);
    res.insert("retry_wire_bytes", resume.retry_wire_bytes);
    res.insert("retry_resumed_bytes", resume.retry_resumed_bytes);
    res.insert("retry_fraction", Json::Num(resume.retry_fraction()));
    root.insert("resume", Json::Obj(res));
    let mut st = JsonObj::new();
    st.insert("pack_bytes", stream.pack_bytes);
    st.insert("largest_object", stream.largest_object);
    st.insert("peak_heap_bytes", stream.peak_heap_bytes);
    st.insert("peak_ratio", Json::Num(stream.peak_ratio));
    st.insert("http_connects", stream.http_connects);
    st.insert("requests", stream.requests);
    root.insert("stream", Json::Obj(st));
    Json::Obj(root)
}

/// Fixed configuration of the `+delta` ablation row: 64 groups of
/// 8192 f32s (~32 KiB per group) keeps the sample fast while leaving
/// each group large enough for content-defined chunking to bite.
const DELTA_GROUPS: usize = 64;
const DELTA_ELEMS: usize = 8192;

/// Run only the `+delta` row and merge it into an existing
/// `BENCH_transfer.json` (creating a minimal one when absent) — the
/// per-PR smoke re-measures the locked ratio without paying for the
/// full ablation.
fn run_delta_cli(args: &[String]) -> Result<()> {
    let groups = args
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DELTA_GROUPS);
    let elems = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(DELTA_ELEMS);
    let sample = run_delta_sample(groups, elems)?;
    print!("{}", render_delta(groups, elems, &sample));
    let fetch = run_fetch_delta_sample(groups, elems)?;
    print!("{}", render_fetch_delta(groups, elems, &fetch));
    let path = std::path::PathBuf::from("BENCH_transfer.json");
    let mut root = match std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
    {
        Some(Json::Obj(o)) => o,
        _ => {
            let mut o = JsonObj::new();
            o.insert("bench", "transfer");
            o
        }
    };
    root.insert("delta", delta_to_json(groups, elems, &sample, &fetch));
    let path = super::write_bench_json("transfer", Json::Obj(root))?;
    println!("wrote {}", path.display());
    Ok(())
}

/// `git-theta bench transfer [groups elems | --delta [groups elems]]`
/// entry point.
pub fn run_transfer_cli(args: &[String]) -> Result<()> {
    if args.first().map(|s| s.as_str()) == Some("--delta") {
        return run_delta_cli(&args[1..]);
    }
    let groups = args
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100usize);
    let elems = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096usize);
    let runs = run_compare(groups, elems)?;
    print!("{}", render_runs(groups, elems, &runs));
    let resume = run_resume_sample(groups, elems)?;
    print!("{}", render_resume(&resume));
    // The stream sample uses its own fixed, larger configuration: the
    // peak-heap bound is only meaningful when the pack dwarfs the
    // per-object streaming window (1024 × 32 KiB objects ≈ 32 MiB raw).
    let stream = run_stream_sample(1024, 8192)?;
    print!("{}", render_stream(&stream));
    let delta = run_delta_sample(DELTA_GROUPS, DELTA_ELEMS)?;
    print!("{}", render_delta(DELTA_GROUPS, DELTA_ELEMS, &delta));
    let fetch = run_fetch_delta_sample(DELTA_GROUPS, DELTA_ELEMS)?;
    print!("{}", render_fetch_delta(DELTA_GROUPS, DELTA_ELEMS, &fetch));
    let mut root = match runs_to_json(groups, elems, &runs, &resume, &stream) {
        Json::Obj(o) => o,
        other => anyhow::bail!("runs_to_json produced a non-object: {other:?}"),
    };
    root.insert("delta", delta_to_json(DELTA_GROUPS, DELTA_ELEMS, &delta, &fetch));
    let path = super::write_bench_json("transfer", Json::Obj(root))?;
    println!("wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_beats_per_object_on_100_group_model() {
        let runs = run_compare(100, 1024).unwrap();
        let per = &runs[0];
        let packed = &runs[1];
        assert_eq!(per.mode, "per-object");
        assert_eq!(packed.mode, "packed");

        // Packed: 1 negotiation + 1 pack per leg. Per-object (the
        // seed's engine): one copy request per group, plus the upload
        // leg's single negotiation.
        assert_eq!(packed.up.round_trips(), 2);
        assert_eq!(per.up.round_trips(), 101);
        assert_eq!(packed.down.round_trips(), 2);
        assert_eq!(per.down.round_trips(), 100);
        assert_eq!(packed.up.packs, 1);
        assert_eq!(packed.down.packs, 1);

        // Same objects moved; fewer bytes on the wire (zstd framing).
        assert_eq!(packed.up.objects, per.up.objects);
        assert!(
            packed.up.packed_bytes < per.up.packed_bytes,
            "packed wire {} >= per-object wire {}",
            packed.up.packed_bytes,
            per.up.packed_bytes
        );
        assert!(packed.down.packed_bytes < per.down.packed_bytes);
    }

    #[test]
    fn http_rows_match_packed_and_resume_halves_the_retry() {
        let runs = run_compare(24, 512).unwrap();
        let packed = &runs[1];
        let http = &runs[2];
        assert_eq!(http.mode, "http");
        // Transport parity: identical logical round trips and payloads.
        assert_eq!(http.up.round_trips(), packed.up.round_trips());
        assert_eq!(http.down.round_trips(), packed.down.round_trips());
        assert_eq!(http.up.objects, packed.up.objects);
        assert_eq!(http.up.packed_bytes, packed.up.packed_bytes);
        assert_eq!(http.down.raw_bytes, packed.down.raw_bytes);

        let sample = run_resume_sample(24, 512).unwrap();
        assert_eq!(sample.retry_resumed_bytes, sample.killed_after);
        assert_eq!(
            sample.retry_wire_bytes + sample.retry_resumed_bytes,
            sample.pack_bytes
        );
        assert!(
            sample.retry_wire_bytes < sample.pack_bytes,
            "resume must transfer strictly fewer bytes than a from-scratch retry"
        );
    }

    #[test]
    fn delta_sample_undercuts_half_the_full_push() {
        // Small config for test speed; the CLI runs the locked 64x8192.
        let s = run_delta_sample(8, 2048).unwrap();
        assert_eq!(s.delta_objects, 8, "every fine-tuned group should ship as a delta");
        assert_eq!(s.round_trips, 2, "chains must ride the one negotiation + one pack");
        assert!(
            s.ratio < 0.5,
            "delta push ratio {} must stay under half the full push",
            s.ratio
        );
    }

    #[test]
    fn fetch_delta_sample_undercuts_half_and_hits_the_plan_cache() {
        // Small config for test speed; the CLI runs the locked 64x8192.
        let s = run_fetch_delta_sample(8, 2048).unwrap();
        assert_eq!(s.delta_objects, 8, "every fine-tuned group should arrive as a delta");
        assert_eq!(s.round_trips, 2, "chains must ride the one negotiation + one pack");
        assert!(
            s.ratio < 0.5,
            "delta fetch ratio {} must stay under half the flat fetch",
            s.ratio
        );
        assert!(
            s.plan_cache_hits >= 8,
            "superset re-fetch should hit the plan cache, got {}",
            s.plan_cache_hits
        );
    }

    #[test]
    fn stream_sample_reuses_one_connection_for_the_round_trip() {
        // Small config for test speed; the CLI runs the full-size one.
        let sample = run_stream_sample(48, 1024).unwrap();
        // 4 logical round trips (2 negotiations + 2 packs); the real
        // HTTP request count is higher still (HEAD probe, pack POST).
        assert!(sample.requests >= 4, "expected ≥4 round trips, got {}", sample.requests);
        assert_eq!(
            sample.http_connects, 1,
            "a sequential push + fetch must ride one keep-alive connection"
        );
        assert!(sample.pack_bytes > 0);
        assert!(sample.largest_object > 0);
        // The library test binary installs no tracking allocator, so
        // the heap counter must report "untracked" (0), never garbage.
        if !crate::util::alloc::active() {
            assert_eq!(sample.peak_heap_bytes, 0);
        }
    }
}
