//! Transfer-engine ablation: per-object vs packed LFS movement.
//!
//! Builds a synthetic model store — N parameter-group objects of
//! bf16-valued f32 data (the Table 1 compressibility profile) — and
//! moves it through both transfer engines in both directions,
//! reporting round trips (negotiations), wire bytes, and wall-clock.
//! Over a real network the round-trip column is the one that matters:
//! per-object transfer pays one copy request per group, the pack
//! engine pays one negotiation plus one pack per model.

use super::time_once;
use crate::gitcore::object::Oid;
use crate::lfs::{batch, LfsRemote, LfsStore};
use crate::util::humansize;
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use anyhow::Result;

/// Measurements for one engine: upload + download legs.
#[derive(Debug, Clone)]
pub struct TransferRun {
    /// Engine name ("per-object" or "packed").
    pub mode: &'static str,
    /// Wall-clock seconds for the upload leg.
    pub upload_secs: f64,
    /// Thread-local transfer counters captured after the upload leg.
    pub up: batch::TransferStats,
    /// Wall-clock seconds for the download leg (fresh clone).
    pub download_secs: f64,
    /// Counters captured after the download leg.
    pub down: batch::TransferStats,
}

/// Synthesize `groups` parameter-group payloads of `elems` f32s each,
/// holding bf16-precision values (low mantissa bytes zero — the
/// compressibility profile of real distributed checkpoints).
pub fn synth_group_payloads(groups: usize, elems: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg64::new(seed);
    (0..groups)
        .map(|_| {
            let mut buf = Vec::with_capacity(elems * 4);
            for _ in 0..elems {
                let v = (rng.next_f32() - 0.5) * 2.0;
                let bf16ish = crate::tensor::bf16_to_f32(crate::tensor::f32_to_bf16(v));
                buf.extend_from_slice(&bf16ish.to_le_bytes());
            }
            buf
        })
        .collect()
}

/// Run both engines over the same `groups`×`elems` synthetic model.
pub fn run_compare(groups: usize, elems: usize) -> Result<Vec<TransferRun>> {
    let td_local = TempDir::new("xfer-local")?;
    let local = LfsStore::open(td_local.path());
    let oids: Vec<Oid> = synth_group_payloads(groups, elems, 42)
        .iter()
        .map(|p| Ok(local.put(p)?.0))
        .collect::<Result<_>>()?;

    let mut runs = Vec::new();
    for mode in ["per-object", "packed"] {
        let td_remote = TempDir::new("xfer-remote")?;
        let remote = LfsRemote::open(td_remote.path());

        // Call the engines directly (not the env-sensitive
        // upload/download fronts) so each row measures what it claims.
        batch::reset_stats();
        let (upload_secs, _) = time_once(|| match mode {
            "per-object" => remote.upload_per_object(&local, &oids).map(|_| ()),
            _ => batch::push_pack(&local, &remote, &oids).map(|_| ()),
        })?;
        let up = batch::stats();

        let td_clone = TempDir::new("xfer-clone")?;
        let clone_store = LfsStore::open(td_clone.path());
        batch::reset_stats();
        let (download_secs, _) = time_once(|| match mode {
            "per-object" => remote.download_per_object(&clone_store, &oids).map(|_| ()),
            _ => batch::fetch_pack(&remote, &clone_store, &oids).map(|_| ()),
        })?;
        let down = batch::stats();

        runs.push(TransferRun {
            mode,
            upload_secs,
            up,
            download_secs,
            down,
        });
    }
    Ok(runs)
}

/// Render the comparison as a paper-style table.
pub fn render_runs(groups: usize, elems: usize, runs: &[TransferRun]) -> String {
    let rows: Vec<Vec<String>> = runs
        .iter()
        .flat_map(|r| {
            vec![
                vec![
                    r.mode.to_string(),
                    "upload".into(),
                    r.up.round_trips().to_string(),
                    r.up.packs.to_string(),
                    humansize::bytes(r.up.packed_bytes),
                    humansize::bytes(r.up.raw_bytes),
                    humansize::duration(r.upload_secs),
                ],
                vec![
                    r.mode.to_string(),
                    "download".into(),
                    r.down.round_trips().to_string(),
                    r.down.packs.to_string(),
                    humansize::bytes(r.down.packed_bytes),
                    humansize::bytes(r.down.raw_bytes),
                    humansize::duration(r.download_secs),
                ],
            ]
        })
        .collect();
    format!(
        "Transfer ablation: {groups} groups x {elems} f32 elems\n{}",
        super::render_table(
            &["Engine", "Leg", "Round trips", "Packs", "Wire", "Raw", "Time"],
            &rows,
        )
    )
}

/// `git-theta bench transfer [groups] [elems]` entry point.
pub fn run_transfer_cli(args: &[String]) -> Result<()> {
    let groups = args
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100usize);
    let elems = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096usize);
    let runs = run_compare(groups, elems)?;
    print!("{}", render_runs(groups, elems, &runs));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_beats_per_object_on_100_group_model() {
        let runs = run_compare(100, 1024).unwrap();
        let per = &runs[0];
        let packed = &runs[1];
        assert_eq!(per.mode, "per-object");
        assert_eq!(packed.mode, "packed");

        // Packed: 1 negotiation + 1 pack per leg. Per-object (the
        // seed's engine): one copy request per group, plus the upload
        // leg's single negotiation.
        assert_eq!(packed.up.round_trips(), 2);
        assert_eq!(per.up.round_trips(), 101);
        assert_eq!(packed.down.round_trips(), 2);
        assert_eq!(per.down.round_trips(), 100);
        assert_eq!(packed.up.packs, 1);
        assert_eq!(packed.down.packs, 1);

        // Same objects moved; fewer bytes on the wire (zstd framing).
        assert_eq!(packed.up.objects, per.up.objects);
        assert!(
            packed.up.packed_bytes < per.up.packed_bytes,
            "packed wire {} >= per-object wire {}",
            packed.up.packed_bytes,
            per.up.packed_bytes
        );
        assert!(packed.down.packed_bytes < per.down.packed_bytes);
    }
}
