//! The paper's §4 benchmark workflow: a community development history
//! replayed over Git LFS (baseline) and Git-Theta.
//!
//! Paper workflow on T0-3B, scaled to a synthetic transformer here:
//! 1. **Add T0 3B** — commit the pre-trained base checkpoint.
//! 2. **Train on CB with LoRA** — low-rank updates to q/v projections.
//! 3. **Fine-Tune on RTE** — full fine-tune on a new branch.
//! 4. **Fine-Tune on ANLI** — full fine-tune on main.
//! 5. **Merge by averaging parameters** — `git merge` (Git-Theta merges
//!    natively; Git LFS commits an externally-merged checkpoint, as in
//!    the paper).
//! 6. **Remove sentinels** — trim sentinel rows from the embedding.
//!
//! For every commit we measure the paper's three metrics: `add`
//! wall-clock (clean filter), `checkout` wall-clock (smudge filter),
//! and the on-disk size of newly stored objects.

use crate::baseline::{LfsBaselineRepo, ThetaRepo};
use crate::benchkit::{render_table, time_once};
use crate::checkpoint::Checkpoint;
use crate::tensor::{bf16_to_f32, f32_to_bf16, weighted_average, Tensor};
use crate::util::humansize;
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use anyhow::{Context, Result};

/// Synthetic transformer configuration.
#[derive(Debug, Clone, Copy)]
pub struct ModelConfig {
    /// Hidden dimension of the synthetic transformer.
    pub d_model: usize,
    /// Transformer blocks.
    pub layers: usize,
    /// Embedding vocabulary rows (excluding sentinels).
    pub vocab: usize,
    /// Sentinel rows appended to the embedding (removed by commit 6).
    pub sentinels: usize,
}

impl ModelConfig {
    /// Default benchmark scale (~15M params), overridable with
    /// `THETA_BENCH_PARAMS` (target millions of parameters).
    pub fn from_env() -> ModelConfig {
        let target_m: f64 = std::env::var("THETA_BENCH_PARAMS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(15.0);
        ModelConfig::with_target_params((target_m * 1e6) as usize)
    }

    /// Pick dimensions for a rough parameter target.
    pub fn with_target_params(target: usize) -> ModelConfig {
        // params ≈ vocab·d + layers·12·d²; fix layers=4, vocab=16·d.
        let layers = 4usize;
        let mut d = 64usize;
        while (ModelConfig { d_model: d * 2, layers, vocab: d * 32, sentinels: 100 }).param_count()
            <= target
        {
            d *= 2;
        }
        ModelConfig {
            d_model: d,
            layers,
            vocab: d * 16,
            sentinels: 100,
        }
    }

    /// Total parameters of the configured model (embedding + blocks).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        (self.vocab + self.sentinels) * d + self.layers * (4 * d * d + 8 * d * d + 2 * d)
    }
}

/// Generate the synthetic pre-trained base checkpoint. Values are
/// bf16-rounded f32 (the paper's T0-3B is "trained using bfloat16
/// precision but distributed as a float32 checkpoint").
pub fn base_model(cfg: &ModelConfig, seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut ck = Checkpoint::new();
    let d = cfg.d_model;
    let tensor = |rng: &mut Pcg64, shape: Vec<usize>, sigma: f32| {
        let n: usize = shape.iter().product();
        let vals: Vec<f32> = (0..n)
            .map(|_| bf16_to_f32(f32_to_bf16(rng.next_gaussian() as f32 * sigma)))
            .collect();
        Tensor::from_f32(shape, vals).unwrap()
    };
    ck.insert(
        "embed/weight",
        tensor(&mut rng, vec![cfg.vocab + cfg.sentinels, d], 0.02),
    );
    for l in 0..cfg.layers {
        for name in ["q", "k", "v", "o"] {
            ck.insert(
                format!("block_{l}/attn/{name}"),
                tensor(&mut rng, vec![d, d], 0.02),
            );
        }
        ck.insert(format!("block_{l}/mlp/wi"), tensor(&mut rng, vec![d, 4 * d], 0.02));
        ck.insert(format!("block_{l}/mlp/wo"), tensor(&mut rng, vec![4 * d, d], 0.02));
        ck.insert(format!("block_{l}/ln1/scale"), tensor(&mut rng, vec![d], 0.01));
        ck.insert(format!("block_{l}/ln2/scale"), tensor(&mut rng, vec![d], 0.01));
    }
    ck
}

/// LoRA-style update: add rank-r deltas to every q/v projection.
pub fn lora_update(ck: &Checkpoint, cfg: &ModelConfig, rank: usize, seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut out = ck.clone();
    for l in 0..cfg.layers {
        for name in ["q", "v"] {
            let key = format!("block_{l}/attn/{name}");
            let w = ck.get(&key).unwrap();
            let (m, n) = (w.shape()[0], w.shape()[1]);
            let a: Vec<f64> = (0..m * rank).map(|_| rng.next_gaussian() * 0.004).collect();
            let b: Vec<f64> = (0..rank * n).map(|_| rng.next_gaussian() * 0.004).collect();
            let wv = w.to_f32_vec().unwrap();
            let mut nv = vec![0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f64;
                    for k in 0..rank {
                        acc += a[i * rank + k] * b[k * n + j];
                    }
                    nv[i * n + j] = (wv[i * n + j] as f64 + acc) as f32;
                }
            }
            out.insert(key, Tensor::from_f32(vec![m, n], nv).unwrap());
        }
    }
    out
}

/// Full fine-tune: perturb every parameter (bf16-rounded).
pub fn fine_tune(ck: &Checkpoint, seed: u64, sigma: f32) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut out = Checkpoint::new();
    for (name, t) in ck.iter() {
        let vals: Vec<f32> = t
            .to_f32_vec()
            .unwrap()
            .iter()
            .map(|&v| bf16_to_f32(f32_to_bf16(v + rng.next_gaussian() as f32 * sigma)))
            .collect();
        out.insert(name.clone(), Tensor::from_f32(t.shape().to_vec(), vals).unwrap());
    }
    out
}

/// External parameter-average (what the LFS baseline must do off-line).
pub fn average_models(a: &Checkpoint, b: &Checkpoint) -> Checkpoint {
    let mut out = Checkpoint::new();
    for (name, ta) in a.iter() {
        let tb = b.get(name).expect("models share parameter groups");
        out.insert(name.clone(), weighted_average(&[ta, tb], &[1.0, 1.0]).unwrap());
    }
    out
}

/// Remove the sentinel rows from the embedding (paper commit 6).
pub fn remove_sentinels(ck: &Checkpoint, cfg: &ModelConfig) -> Checkpoint {
    let mut out = ck.clone();
    let emb = ck.get("embed/weight").unwrap();
    out.insert("embed/weight", emb.take_rows(cfg.vocab).unwrap());
    out
}

/// One measured commit row.
#[derive(Debug, Clone)]
pub struct CommitMeasurement {
    /// Paper name of the commit (one of [`COMMIT_NAMES`]).
    pub name: &'static str,
    /// Clean-filter (`git add`) wall-clock seconds.
    pub add_secs: f64,
    /// Smudge-filter (`git checkout`) wall-clock seconds.
    pub checkout_secs: f64,
    /// Bytes of new objects stored by this commit.
    pub size_bytes: u64,
}

/// Full result of one system's run over the workflow.
#[derive(Debug, Clone)]
pub struct WorkflowResult {
    /// System under measurement ("Git LFS" or "Git-Theta").
    pub system: &'static str,
    /// One measured row per workflow commit, in commit order.
    pub commits: Vec<CommitMeasurement>,
    /// Total object-store bytes after the last commit.
    pub total_bytes: u64,
}

/// The paper's six workflow commits, in order.
pub const COMMIT_NAMES: [&str; 6] = [
    "Add base model",
    "Train on CB with LoRA",
    "Fine-Tune on RTE",
    "Fine-Tune on ANLI",
    "Merge by averaging parameters",
    "Remove sentinels",
];

/// The six model versions of the workflow, in commit order, plus the
/// branch structure implied (RTE is authored on a side branch).
pub struct WorkflowModels {
    /// Commit 1: the pre-trained base checkpoint.
    pub base: Checkpoint,
    /// Commit 2: base + LoRA updates on q/v projections.
    pub cb_lora: Checkpoint,
    /// Commit 3: full fine-tune of `cb_lora` (side branch).
    pub rte: Checkpoint,
    /// Commit 4: full fine-tune of `cb_lora` (main).
    pub anli: Checkpoint,
    /// Commit 5: parameter average of `rte` and `anli`.
    pub merged: Checkpoint,
    /// Commit 6: `merged` with the sentinel embedding rows removed.
    pub trimmed: Checkpoint,
}

/// Build all six model versions of the workflow from one seed.
pub fn build_models(cfg: &ModelConfig, seed: u64) -> WorkflowModels {
    let base = base_model(cfg, seed);
    let cb_lora = lora_update(&base, cfg, 16, seed + 1);
    let rte = fine_tune(&cb_lora, seed + 2, 1e-3);
    let anli = fine_tune(&cb_lora, seed + 3, 1e-3);
    let merged = average_models(&anli, &rte);
    let trimmed = remove_sentinels(&merged, cfg);
    WorkflowModels {
        base,
        cb_lora,
        rte,
        anli,
        merged,
        trimmed,
    }
}

/// Run the workflow through the Git LFS baseline (linear history; the
/// merge is performed externally, as the paper does for LFS).
pub fn run_lfs_workflow(models: &WorkflowModels) -> Result<WorkflowResult> {
    let td = TempDir::new("bench-lfs")?;
    let repo = LfsBaselineRepo::init(td.path(), "model.safetensors")?;
    let sequence = [
        &models.base,
        &models.cb_lora,
        &models.rte,
        &models.anli,
        &models.merged,
        &models.trimmed,
    ];
    let mut commits = Vec::new();
    let mut prev_size = 0u64;
    let mut prev_commit: Option<crate::gitcore::object::Oid> = None;
    for (i, ck) in sequence.iter().enumerate() {
        repo.write_model(ck)?;
        let (add_secs, _) = time_once(|| repo.add())?;
        let commit = repo.commit(COMMIT_NAMES[i])?;
        let size = repo.storage_bytes()?;
        // Time checkout of this commit starting from the previous one.
        let checkout_secs = match prev_commit {
            Some(prev) => {
                repo.checkout(&prev.to_hex())?;
                let (t, _) = time_once(|| repo.checkout(&commit.to_hex()))?;
                t
            }
            None => {
                // First commit: re-checkout itself after clearing the file.
                std::fs::remove_file(repo.repo.worktree().join(&repo.model_path))?;
                let (t, _) = time_once(|| repo.checkout(&commit.to_hex()))?;
                t
            }
        };
        commits.push(CommitMeasurement {
            name: COMMIT_NAMES[i],
            add_secs,
            checkout_secs,
            size_bytes: size - prev_size,
        });
        prev_size = size;
        prev_commit = Some(commit);
    }
    Ok(WorkflowResult {
        system: "Git LFS",
        commits,
        total_bytes: prev_size,
    })
}

/// Run the workflow through Git-Theta with real branching and a native
/// `git merge --strategy average`.
pub fn run_theta_workflow(models: &WorkflowModels) -> Result<WorkflowResult> {
    let td = TempDir::new("bench-theta")?;
    let repo = ThetaRepo::init(td.path(), "model.safetensors")?;
    let mut commits: Vec<CommitMeasurement> = Vec::new();
    let mut prev_size = 0u64;
    let mut measure = |repo: &ThetaRepo,
                       name: &'static str,
                       add_secs: f64,
                       commit: crate::gitcore::object::Oid,
                       prev_commit: Option<crate::gitcore::object::Oid>|
     -> Result<CommitMeasurement> {
        let size = repo.storage_bytes()?;
        let checkout_secs = match prev_commit {
            Some(prev) => {
                repo.checkout(&prev.to_hex())?;
                let (t, _) = time_once(|| repo.checkout(&commit.to_hex()))?;
                t
            }
            None => {
                std::fs::remove_file(repo.repo.worktree().join(&repo.model_path))?;
                let (t, _) = time_once(|| repo.checkout(&commit.to_hex()))?;
                t
            }
        };
        let m = CommitMeasurement {
            name,
            add_secs,
            checkout_secs,
            size_bytes: size - prev_size,
        };
        prev_size = size;
        Ok(m)
    };

    // 1. Add base.
    repo.write_model(&models.base)?;
    let (t_add, _) = time_once(|| repo.add())?;
    let c1 = repo.commit(COMMIT_NAMES[0])?;
    commits.push(measure(&repo, COMMIT_NAMES[0], t_add, c1, None)?);
    repo.checkout(&c1.to_hex())?;
    repo.checkout("main")?;

    // 2. LoRA on CB (main).
    repo.write_model(&models.cb_lora)?;
    let (t_add, _) = time_once(|| repo.add())?;
    let c2 = repo.commit(COMMIT_NAMES[1])?;
    commits.push(measure(&repo, COMMIT_NAMES[1], t_add, c2, Some(c1))?);
    repo.checkout("main")?;

    // 3. RTE on a side branch.
    repo.repo.create_branch("rte")?;
    repo.checkout("rte")?;
    repo.write_model(&models.rte)?;
    let (t_add, _) = time_once(|| repo.add())?;
    let c3 = repo.commit(COMMIT_NAMES[2])?;
    commits.push(measure(&repo, COMMIT_NAMES[2], t_add, c3, Some(c2))?);

    // 4. ANLI on main.
    repo.checkout("main")?;
    repo.write_model(&models.anli)?;
    let (t_add, _) = time_once(|| repo.add())?;
    let c4 = repo.commit(COMMIT_NAMES[3])?;
    commits.push(measure(&repo, COMMIT_NAMES[3], t_add, c4, Some(c3))?);
    repo.checkout("main")?;

    // 5. Native merge with parameter averaging. The paper reports `add`
    //    for LFS's externally-merged checkpoint; for Git-Theta the merge
    //    driver does the equivalent work, so we time the merge itself.
    let (t_merge, c5) = time_once(|| repo.merge_with_strategy("rte", "average"))?;
    commits.push(measure(&repo, COMMIT_NAMES[4], t_merge, c5, Some(c4))?);
    repo.checkout("main")?;

    // 6. Remove sentinels.
    repo.write_model(&models.trimmed)?;
    let (t_add, _) = time_once(|| repo.add())?;
    let c6 = repo.commit(COMMIT_NAMES[5])?;
    commits.push(measure(&repo, COMMIT_NAMES[5], t_add, c6, Some(c5))?);

    Ok(WorkflowResult {
        system: "Git-Theta",
        commits,
        total_bytes: prev_size,
    })
}

/// Render Table 1 from two workflow results.
pub fn render_table1(lfs: &WorkflowResult, theta: &WorkflowResult) -> String {
    let mut rows = Vec::new();
    for (l, t) in lfs.commits.iter().zip(&theta.commits) {
        rows.push(vec![
            l.name.to_string(),
            "add".into(),
            humansize::duration(l.add_secs),
            humansize::duration(t.add_secs),
        ]);
        rows.push(vec![
            String::new(),
            "checkout".into(),
            humansize::duration(l.checkout_secs),
            humansize::duration(t.checkout_secs),
        ]);
        rows.push(vec![
            String::new(),
            "Size".into(),
            humansize::bytes(l.size_bytes),
            humansize::bytes(t.size_bytes),
        ]);
    }
    rows.push(vec![
        "Total".into(),
        "Size".into(),
        humansize::bytes(lfs.total_bytes),
        humansize::bytes(theta.total_bytes),
    ]);
    render_table(&["Commit", "Metric", "Git LFS", "Git-Theta"], &rows)
}

/// Figure 2 series: relative space saving per commit.
pub fn figure2_series(lfs: &WorkflowResult, theta: &WorkflowResult) -> Vec<(String, f64)> {
    lfs.commits
        .iter()
        .zip(&theta.commits)
        .map(|(l, t)| {
            let saving = 1.0 - t.size_bytes as f64 / l.size_bytes.max(1) as f64;
            (l.name.to_string(), saving)
        })
        .collect()
}

/// Render Figure 2 as an ASCII bar chart.
pub fn render_figure2(series: &[(String, f64)]) -> String {
    let mut out = String::from("Relative space saving of Git-Theta over Git LFS\n");
    for (name, saving) in series {
        let pct = saving * 100.0;
        let bars = "#".repeat(((pct.max(0.0) / 2.0) as usize).min(50));
        out.push_str(&format!("{name:<32} {pct:>7.2}% |{bars}\n"));
    }
    out
}

/// `git-theta bench table1` entry point.
pub fn run_table1_cli(_args: &[String]) -> Result<()> {
    let cfg = ModelConfig::from_env();
    eprintln!(
        "workflow model: d={} layers={} vocab={} (+{} sentinels) = {:.1}M params",
        cfg.d_model,
        cfg.layers,
        cfg.vocab,
        cfg.sentinels,
        cfg.param_count() as f64 / 1e6
    );
    let models = build_models(&cfg, 42);
    let lfs = run_lfs_workflow(&models).context("lfs workflow")?;
    let theta = run_theta_workflow(&models).context("theta workflow")?;
    println!("{}", render_table1(&lfs, &theta));
    Ok(())
}

/// `git-theta bench figure2` entry point.
pub fn run_figure2_cli(_args: &[String]) -> Result<()> {
    let cfg = ModelConfig::from_env();
    let models = build_models(&cfg, 42);
    let lfs = run_lfs_workflow(&models)?;
    let theta = run_theta_workflow(&models)?;
    println!("{}", render_figure2(&figure2_series(&lfs, &theta)));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            d_model: 32,
            layers: 2,
            vocab: 128,
            sentinels: 16,
        }
    }

    #[test]
    fn model_config_scaling() {
        let cfg = ModelConfig::with_target_params(15_000_000);
        let p = cfg.param_count();
        assert!(p > 2_000_000 && p < 16_000_000, "params {p}");
    }

    #[test]
    fn workflow_models_are_consistent() {
        let cfg = tiny_cfg();
        let m = build_models(&cfg, 1);
        assert_eq!(m.base.len(), m.cb_lora.len());
        // LoRA only touches q/v.
        assert_eq!(m.base.get("block_0/attn/k"), m.cb_lora.get("block_0/attn/k"));
        assert_ne!(m.base.get("block_0/attn/q"), m.cb_lora.get("block_0/attn/q"));
        // Trim removed sentinel rows.
        assert_eq!(
            m.trimmed.get("embed/weight").unwrap().shape()[0],
            cfg.vocab
        );
    }

    #[test]
    fn end_to_end_tiny_workflow() {
        let cfg = tiny_cfg();
        let models = build_models(&cfg, 2);
        let lfs = run_lfs_workflow(&models).unwrap();
        let theta = run_theta_workflow(&models).unwrap();
        assert_eq!(lfs.commits.len(), 6);
        assert_eq!(theta.commits.len(), 6);

        // The paper's qualitative claims, at tiny scale:
        // LoRA commit: theta stores far less than LFS.
        assert!(theta.commits[1].size_bytes * 4 < lfs.commits[1].size_bytes);
        // Trim commit: theta stores almost nothing.
        assert!(theta.commits[5].size_bytes * 10 < lfs.commits[5].size_bytes);
        // Total: theta smaller overall.
        assert!(theta.total_bytes < lfs.total_bytes);

        let table = render_table1(&lfs, &theta);
        assert!(table.contains("Train on CB with LoRA"));
        let fig2 = figure2_series(&lfs, &theta);
        assert_eq!(fig2.len(), 6);
        assert!(fig2[1].1 > 0.5, "LoRA saving {:?}", fig2[1]);
    }
}
