//! Checkout-engine ablation: smudge cost vs chain depth with each
//! optimization toggled.
//!
//! Synthesizes a continually-trained model — a dense base commit
//! followed by `depth - 1` sparse update commits per parameter group —
//! twice: once with chain snapshotting disabled (the unbounded chain a
//! pre-engine repository accumulates) and once with the default
//! [`DEFAULT_SNAPSHOT_DEPTH`] policy. It then measures smudge
//! wall-clock and peak transient heap (when the running binary
//! installed [`util::alloc::TrackingAlloc`](crate::util::alloc)) under
//! each combination of the engine's three levers:
//!
//! * **snapshot** — bounded vs unbounded chain depth,
//! * **cache** — per-run memoized reconstruction on/off,
//! * **in-place decode** — scatter decode vs the legacy copying path.
//!
//! Every synthesized version is verified to smudge back to the exact
//! checkpoint that produced it (clean → smudge identity at every
//! depth), so a config that "wins" by decoding garbage cannot pass.

use super::{render_table, time_n};
use crate::checkpoint::Checkpoint;
use crate::lfs::LfsStore;
use crate::tensor::Tensor;
use crate::theta::filter::{
    clean_checkpoint_opts, smudge_metadata_opts, CleanOptions, ObjectAccess,
};
use crate::theta::metadata::ModelMetadata;
use crate::theta::serialize::set_legacy_decode;
use crate::theta::DEFAULT_SNAPSHOT_DEPTH;
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Pcg64;
use crate::util::tmp::TempDir;
use crate::util::{alloc, humansize, par};
use anyhow::{ensure, Result};

/// One measured smudge configuration.
#[derive(Debug, Clone)]
pub struct CheckoutRun {
    /// Which levers were on.
    pub label: &'static str,
    /// Chain depth of the deepest group in the smudged metadata.
    pub chain_depth: usize,
    /// Mean smudge wall-clock seconds.
    pub smudge_secs: f64,
    /// Peak transient heap of one smudge, when the binary tracks it.
    pub peak_bytes: Option<usize>,
}

/// The two synthesized histories plus the checkpoint they both encode.
pub struct ChainFixture {
    /// Object store backing both histories (content-addressed, shared).
    pub access: ObjectAccess,
    /// Final metadata with snapshotting disabled (full-depth chains).
    pub deep: ModelMetadata,
    /// Final metadata under the default snapshot policy.
    pub snapshotted: ModelMetadata,
    /// The checkpoint every final metadata must smudge back to.
    pub final_ck: Checkpoint,
    /// Keeps the store directory alive for the fixture's lifetime.
    _dir: TempDir,
}

/// Synthesize `depth` versions of a `groups`×`elems` model and clean
/// them through both snapshot policies, verifying clean → smudge
/// identity at every intermediate depth.
pub fn build_fixture(groups: usize, elems: usize, depth: usize) -> Result<ChainFixture> {
    let dir = TempDir::new("bench-checkout")?;
    let access = ObjectAccess {
        store: LfsStore::open(dir.path()),
        remote: None,
    };
    let threads = par::default_threads();
    let mut rng = Pcg64::new(0xC0DE);
    let mut ck = Checkpoint::new();
    for g in 0..groups {
        let vals: Vec<f32> = (0..elems).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
        ck.insert(format!("block{g}/w"), Tensor::from_f32(vec![elems], vals)?);
    }

    let deep_opts = CleanOptions {
        snapshot_depth: None,
        threads,
        ..Default::default()
    };
    let snap_opts = CleanOptions {
        snapshot_depth: Some(DEFAULT_SNAPSHOT_DEPTH),
        threads,
        ..Default::default()
    };
    let mut deep = clean_checkpoint_opts(&access, &ck, "native", None, &deep_opts)?;
    let mut snapshotted = clean_checkpoint_opts(&access, &ck, "native", None, &snap_opts)?;

    for v in 1..depth {
        // Touch ~1/64 of each group's elements: comfortably sparse, so
        // every version appends one incremental link.
        for g in 0..groups {
            let name = format!("block{g}/w");
            let mut vals = ck.get(&name).unwrap().to_f32_vec()?;
            for k in 0..(elems / 64).max(1) {
                let at = (v * 31 + k * 97 + g * 13) % elems;
                vals[at] = (rng.next_f32() - 0.5) * 0.2;
            }
            ck.insert(name, Tensor::from_f32(vec![elems], vals)?);
        }
        deep = clean_checkpoint_opts(&access, &ck, "native", Some(&deep), &deep_opts)?;
        snapshotted =
            clean_checkpoint_opts(&access, &ck, "native", Some(&snapshotted), &snap_opts)?;

        // Identity must hold at every depth, for both histories.
        ensure!(
            smudge_metadata_opts(&access, &deep, threads, true)? == ck,
            "deep history diverged at depth {}",
            v + 1
        );
        ensure!(
            smudge_metadata_opts(&access, &snapshotted, threads, true)? == ck,
            "snapshotted history diverged at depth {}",
            v + 1
        );
    }
    Ok(ChainFixture {
        access,
        deep,
        snapshotted,
        final_ck: ck,
        _dir: dir,
    })
}

fn max_depth(meta: &ModelMetadata) -> usize {
    meta.groups.values().map(|g| g.chain_depth()).max().unwrap_or(0)
}

/// Measure one configuration: `warmup + samples` timed smudges plus one
/// allocation-tracked smudge.
fn measure(
    label: &'static str,
    access: &ObjectAccess,
    meta: &ModelMetadata,
    expect: &Checkpoint,
    cache: bool,
    legacy_decode: bool,
) -> Result<CheckoutRun> {
    let threads = par::default_threads();
    set_legacy_decode(legacy_decode);
    let result = (|| -> Result<CheckoutRun> {
        ensure!(
            smudge_metadata_opts(access, meta, threads, cache)? == *expect,
            "config '{label}' smudged a different checkpoint"
        );
        let stats = time_n(1, 3, || {
            smudge_metadata_opts(access, meta, threads, cache).map(|_| ())
        })?;
        let peak_bytes = if alloc::active() {
            let base = alloc::reset_peak();
            smudge_metadata_opts(access, meta, threads, cache)?;
            Some(alloc::peak_bytes().saturating_sub(base))
        } else {
            None
        };
        Ok(CheckoutRun {
            label,
            chain_depth: max_depth(meta),
            smudge_secs: stats.mean(),
            peak_bytes,
        })
    })();
    set_legacy_decode(false);
    result
}

/// Run the full ablation over a prepared fixture.
///
/// Row order: all-off, +snapshot, +cache, +in-place, all-on, then the
/// fresh-dense (depth-1) regression pair. The all-on/all-off ratio is
/// the headline speedup; the fresh-dense pair guards against the
/// in-place decoder regressing the cold-checkout path.
pub fn run_ablation(fixture: &ChainFixture) -> Result<Vec<CheckoutRun>> {
    let acc = &fixture.access;
    let ck = &fixture.final_ck;
    let mut runs = vec![
        measure("all off", acc, &fixture.deep, ck, false, true)?,
        measure("+snapshot", acc, &fixture.snapshotted, ck, false, true)?,
        measure("+cache", acc, &fixture.deep, ck, true, true)?,
        measure("+in-place decode", acc, &fixture.deep, ck, false, false)?,
        measure("all on", acc, &fixture.snapshotted, ck, true, false)?,
    ];

    // Fresh dense model (depth 1): the engine must not regress the
    // cold-checkout path that has no chains to optimize.
    let threads = par::default_threads();
    let dense = clean_checkpoint_opts(
        acc,
        ck,
        "native",
        None,
        &CleanOptions {
            threads,
            ..Default::default()
        },
    )?;
    // Same cache setting on both rows: this pair isolates the decode
    // path, nothing else.
    runs.push(measure("fresh dense, copying", acc, &dense, ck, false, true)?);
    runs.push(measure("fresh dense, in-place", acc, &dense, ck, false, false)?);
    Ok(runs)
}

/// Render the ablation as a paper-style table.
pub fn render_runs(groups: usize, elems: usize, runs: &[CheckoutRun]) -> String {
    let baseline = runs.first().map(|r| r.smudge_secs).unwrap_or(0.0);
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.label.to_string(),
                r.chain_depth.to_string(),
                humansize::duration(r.smudge_secs),
                match r.peak_bytes {
                    Some(b) => humansize::bytes(b as u64),
                    None => "n/a".to_string(),
                },
                format!("{:.2}x", baseline / r.smudge_secs.max(1e-12)),
            ]
        })
        .collect();
    format!(
        "Checkout ablation: {groups} groups x {elems} f32 elems\n{}",
        render_table(
            &["Engine config", "Depth", "Smudge", "Peak alloc", "Speedup"],
            &rows,
        )
    )
}

/// Encode the ablation as the machine-readable `BENCH_checkout.json`
/// payload (perf trajectory tracking across PRs).
pub fn runs_to_json(depth: usize, groups: usize, elems: usize, runs: &[CheckoutRun]) -> Json {
    let baseline = runs.first().map(|r| r.smudge_secs).unwrap_or(0.0);
    let mut root = JsonObj::new();
    root.insert("bench", "checkout");
    root.insert("depth", depth);
    root.insert("groups", groups);
    root.insert("elems", elems);
    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            let mut o = JsonObj::new();
            o.insert("label", r.label);
            o.insert("chain_depth", r.chain_depth);
            o.insert("smudge_secs", Json::Num(r.smudge_secs));
            o.insert(
                "peak_bytes",
                match r.peak_bytes {
                    Some(b) => Json::from(b),
                    None => Json::Null,
                },
            );
            o.insert(
                "speedup_vs_all_off",
                Json::Num(baseline / r.smudge_secs.max(1e-12)),
            );
            Json::Obj(o)
        })
        .collect();
    root.insert("runs", Json::Arr(rows));
    Json::Obj(root)
}

/// `git-theta bench checkout [depth] [groups] [elems]` entry point.
pub fn run_checkout_cli(args: &[String]) -> Result<()> {
    let depth = args.first().and_then(|s| s.parse().ok()).unwrap_or(32usize);
    let groups = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4usize);
    let elems = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(262_144usize);
    let fixture = build_fixture(groups, elems, depth)?;
    println!("clean -> smudge identity verified at every depth 1..={depth} (both histories)");
    let runs = run_ablation(&fixture)?;
    print!("{}", render_runs(groups, elems, &runs));
    let path = super::write_bench_json("checkout", runs_to_json(depth, groups, elems, &runs))?;
    println!("wrote {}", path.display());
    if !alloc::active() {
        println!("note: peak-alloc tracking inactive (this binary did not install TrackingAlloc)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_small_model_end_to_end() {
        // Small but deep: exercises snapshotting (> default threshold),
        // both decode paths, and identity verification at every depth.
        let fixture = build_fixture(2, 2048, 12).unwrap();
        assert_eq!(max_depth(&fixture.deep), 12);
        assert!(max_depth(&fixture.snapshotted) <= DEFAULT_SNAPSHOT_DEPTH);
        let runs = run_ablation(&fixture).unwrap();
        assert_eq!(runs.len(), 7);
        // Depth column: deep rows at 12, snapshotted bounded, dense at 1.
        assert_eq!(runs[0].chain_depth, 12);
        assert!(runs[1].chain_depth <= DEFAULT_SNAPSHOT_DEPTH);
        assert_eq!(runs[5].chain_depth, 1);
        assert_eq!(runs[6].chain_depth, 1);
        let table = render_runs(2, 2048, &runs);
        assert!(table.contains("all on"));
        assert!(table.contains("fresh dense, in-place"));
    }
}
