//! Locality-sensitive hashing for parameter-group change detection
//! (paper §3.3 "Locality Sensitive Hash").
//!
//! Bitwise hashes are unreliable for model parameters: a single-ulp
//! difference from nondeterministic floating point produces a different
//! digest. Git-Theta instead uses a Euclidean LSH (Datar et al., 2004)
//! with the random-pool trick of Van Durme & Lall (2010) so weights of
//! any size hash against a fixed pool of Gaussians:
//!
//! * A pool matrix `R ∈ R^{POOL×K}` of standard Gaussians is generated
//!   once from a fixed seed (identical in Rust and in the Pallas kernel,
//!   both seeded PCG64 — see `python/compile/kernels/lsh.py`).
//! * A parameter vector `x` of any length is folded cyclically:
//!   `y_j = Σ_i x_i · R[i mod POOL, j]` — i.e. reshape x into rows of
//!   length POOL (zero-padded) and matmul with R, which is exactly the
//!   kernel-friendly pooled-projection the Pallas kernel implements.
//! * Bucketing: `h_j = floor((y_j + b_j) / W)` with per-hash offsets
//!   `b_j ~ U[0, W)`.
//!
//! K = 16 hash functions. W is calibrated (see [`BUCKET_WIDTH`]) so two
//! parameter groups with Euclidean distance ≤ 1e-8 receive identical
//! signatures with probability ≥ 0.99. Signatures also carry the raw
//! projections, which give an unbiased distance estimate between two
//! versions; estimates inside the ambiguous band
//! [`DIST_LOWER`, `DIST_UPPER`] trigger an exact `allclose` check
//! (paper: "weights that have a Euclidean distance ∈ [1e-8, 1e-6] are
//! checked with np.allclose").

use crate::tensor::Tensor;
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};
use once_cell::sync::Lazy;

/// Number of hash functions (paper: "Git-Theta's LSH uses 16").
pub const NUM_HASHES: usize = 16;

/// Random-pool size (gaussian rows of the projection matrix).
pub const POOL_SIZE: usize = 16384;

/// Seed shared with the Pallas kernel generator.
pub const LSH_SEED: u64 = 0x7e7a_0001;

/// Distance below which two groups are definitely "unchanged".
pub const DIST_LOWER: f64 = 1e-8;

/// Distance above which two groups are definitely "changed".
pub const DIST_UPPER: f64 = 1e-6;

/// Bucket width W.
///
/// Calibration: for ‖x−y‖ = d, each projection difference is N(0, d²),
/// so P[bucket boundary crossed] = E|δ|/W = d·√(2/π)/W for d ≪ W. The
/// union bound over K=16 hashes gives
/// P[signature differs] ≤ K·d·√(2/π)/W. Requiring ≤ 1% at d = 1e-8:
/// W ≥ 16·0.79788·1e-8/0.01 ≈ 1.277e-5. We round up to 1.3e-5.
pub const BUCKET_WIDTH: f64 = 1.3e-5;

/// The (POOL_SIZE × NUM_HASHES) projection matrix + per-hash offsets.
pub struct LshParams {
    /// Row-major [POOL_SIZE][NUM_HASHES] standard Gaussians.
    pub pool: Vec<f32>,
    /// f64 copy of the pool (hot-path: avoids per-element widening).
    pub pool_f64: Vec<f64>,
    /// Offsets b_j ∈ [0, W).
    pub offsets: [f64; NUM_HASHES],
}

static PARAMS: Lazy<LshParams> = Lazy::new(|| {
    let mut rng = Pcg64::new(LSH_SEED);
    let mut pool = vec![0f32; POOL_SIZE * NUM_HASHES];
    for v in pool.iter_mut() {
        *v = rng.next_gaussian() as f32;
    }
    let mut offsets = [0f64; NUM_HASHES];
    for o in offsets.iter_mut() {
        *o = rng.next_f64() * BUCKET_WIDTH;
    }
    let pool_f64 = pool.iter().map(|&v| v as f64).collect();
    LshParams { pool, pool_f64, offsets }
});

/// Shared LSH parameters (generated once per process).
pub fn params() -> &'static LshParams {
    &PARAMS
}

/// An LSH signature: bucket ids plus the raw projections they came from.
#[derive(Debug, Clone, PartialEq)]
pub struct LshSignature {
    /// Quantized bucket id per hash function: `floor((y_j + b_j) / W)`.
    pub buckets: [i64; NUM_HASHES],
    /// Raw pooled projections `y_j` the buckets were derived from;
    /// kept because projection deltas give an unbiased distance
    /// estimate between two versions ([`LshSignature::distance_estimate`]).
    pub projections: [f64; NUM_HASHES],
}

impl LshSignature {
    /// Hash a tensor (any float dtype; elements promoted to f32).
    pub fn of_tensor(t: &Tensor) -> Result<LshSignature> {
        let values = t.to_f32_vec().context("LSH requires a float tensor")?;
        Ok(Self::of_values(&values))
    }

    /// Hash raw f32 values via pooled projection.
    pub fn of_values(values: &[f32]) -> LshSignature {
        let proj = project(values);
        Self::from_projections(proj)
    }

    /// Bucket precomputed projections.
    pub fn from_projections(projections: [f64; NUM_HASHES]) -> LshSignature {
        let p = params();
        let mut buckets = [0i64; NUM_HASHES];
        for j in 0..NUM_HASHES {
            buckets[j] = ((projections[j] + p.offsets[j]) / BUCKET_WIDTH).floor() as i64;
        }
        LshSignature {
            buckets,
            projections,
        }
    }

    /// Unbiased estimate of the Euclidean distance to another version,
    /// from the projection deltas: E[(δ_j)²] = d².
    pub fn distance_estimate(&self, other: &LshSignature) -> f64 {
        let mut acc = 0f64;
        for j in 0..NUM_HASHES {
            let d = self.projections[j] - other.projections[j];
            acc += d * d;
        }
        (acc / NUM_HASHES as f64).sqrt()
    }

    /// Change-detection verdict versus a previous signature.
    pub fn compare(&self, prev: &LshSignature) -> LshVerdict {
        if self.buckets != prev.buckets {
            return LshVerdict::Changed;
        }
        let d = self.distance_estimate(prev);
        if d <= DIST_LOWER {
            LshVerdict::Unchanged
        } else if d <= DIST_UPPER {
            LshVerdict::NeedsExactCheck
        } else {
            LshVerdict::Changed
        }
    }

    /// Encode for embedding in the metadata file Git versions.
    pub fn to_json(&self) -> Json {
        let mut obj = JsonObj::new();
        obj.insert(
            "buckets",
            Json::Arr(self.buckets.iter().map(|&b| Json::from(b)).collect()),
        );
        obj.insert(
            "proj",
            Json::Arr(self.projections.iter().map(|&p| Json::Num(p)).collect()),
        );
        Json::Obj(obj)
    }

    /// Decode a signature previously written by [`LshSignature::to_json`].
    pub fn from_json(json: &Json) -> Result<LshSignature> {
        let buckets_arr = json
            .get("buckets")
            .and_then(|v| v.as_arr())
            .context("lsh missing buckets")?;
        let proj_arr = json
            .get("proj")
            .and_then(|v| v.as_arr())
            .context("lsh missing proj")?;
        if buckets_arr.len() != NUM_HASHES || proj_arr.len() != NUM_HASHES {
            anyhow::bail!("lsh signature must have {NUM_HASHES} entries");
        }
        let mut buckets = [0i64; NUM_HASHES];
        let mut projections = [0f64; NUM_HASHES];
        for j in 0..NUM_HASHES {
            buckets[j] = buckets_arr[j].as_i64().context("bad bucket")?;
            projections[j] = proj_arr[j].as_f64().context("bad projection")?;
        }
        Ok(LshSignature {
            buckets,
            projections,
        })
    }
}

/// Result of an LSH comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LshVerdict {
    /// Equal buckets and distance estimate ≤ [`DIST_LOWER`]: the
    /// values are the same to the paper's 1e-8 bound.
    Unchanged,
    /// Distance estimate in the ambiguous band: run `allclose`
    /// (`theta::checkout::values_equal_exact` is the fallback).
    NeedsExactCheck,
    /// Different buckets, or distance estimate > [`DIST_UPPER`].
    Changed,
}

/// Pooled projection: y_j = Σ_i x_i · R[i mod POOL, j].
///
/// This is the pure-Rust hot path; `mlops::lsh_project` can route large
/// tensors through the AOT Pallas kernel instead (bit-identical pool).
pub fn project(values: &[f32]) -> [f64; NUM_HASHES] {
    let p = params();
    let mut acc = [0f64; NUM_HASHES];
    // Process in pool-sized rows; branch-free 16-wide inner loop over a
    // pre-widened f64 pool (§Perf: ~1.6x over the naive loop).
    let mut offset = 0usize;
    while offset < values.len() {
        let row_len = (values.len() - offset).min(POOL_SIZE);
        let row = &values[offset..offset + row_len];
        for (i, &x) in row.iter().enumerate() {
            let base = i * NUM_HASHES;
            let r = &p.pool[base..base + NUM_HASHES];
            let x = x as f64;
            for j in 0..NUM_HASHES {
                acc[j] += x * r[j] as f64;
            }
        }
        offset += row_len;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_values(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect()
    }

    #[test]
    fn deterministic_signatures() {
        let v = random_values(1, 5000);
        let a = LshSignature::of_values(&v);
        let b = LshSignature::of_values(&v);
        assert_eq!(a, b);
    }

    #[test]
    fn identical_tensors_unchanged() {
        let v = random_values(2, 40_000);
        let a = LshSignature::of_values(&v);
        let b = LshSignature::of_values(&v.clone());
        assert_eq!(a.compare(&b), LshVerdict::Unchanged);
    }

    #[test]
    fn tiny_noise_below_1e8_matches() {
        // Perturb so total L2 distance is ~1e-9 (< DIST_LOWER).
        let v = random_values(3, 10_000);
        let mut w = v.clone();
        let per_elem = 1e-9f32 / (w.len() as f32).sqrt();
        for x in w.iter_mut() {
            *x += per_elem;
        }
        let a = LshSignature::of_values(&v);
        let b = LshSignature::of_values(&w);
        assert_eq!(a.compare(&b), LshVerdict::Unchanged);
    }

    #[test]
    fn real_training_updates_detected() {
        // A realistic update has distance ≫ 1e-6.
        let v = random_values(4, 10_000);
        let mut w = v.clone();
        w[5] += 0.01;
        let a = LshSignature::of_values(&v);
        let b = LshSignature::of_values(&w);
        assert_eq!(a.compare(&b), LshVerdict::Changed);
    }

    #[test]
    fn ambiguous_band_flags_exact_check() {
        let v = random_values(5, 10_000);
        let mut w = v.clone();
        // Distance ~1e-7: inside [1e-8, 1e-6].
        let per_elem = 1e-7f32 / (w.len() as f32).sqrt();
        for x in w.iter_mut() {
            *x += per_elem;
        }
        let a = LshSignature::of_values(&v);
        let b = LshSignature::of_values(&w);
        // Buckets may occasionally differ (that's also a safe outcome);
        // when they agree the verdict must be the exact check.
        let verdict = a.compare(&b);
        assert!(
            verdict == LshVerdict::NeedsExactCheck || verdict == LshVerdict::Changed,
            "{verdict:?}"
        );
    }

    #[test]
    fn distance_estimator_is_accurate() {
        let v = random_values(6, 50_000);
        // Targets large enough that the per-element f32 perturbation is
        // not absorbed by rounding against ~0.1-magnitude values.
        for target in [1e-4f64, 1e-2, 1.0] {
            let mut w = v.clone();
            let per_elem = (target / (w.len() as f64).sqrt()) as f32;
            for x in w.iter_mut() {
                *x += per_elem;
            }
            let a = LshSignature::of_values(&v);
            let b = LshSignature::of_values(&w);
            let est = a.distance_estimate(&b);
            assert!(
                est > target * 0.4 && est < target * 2.5,
                "target {target} est {est}"
            );
        }
    }

    #[test]
    fn calibration_false_positive_rate() {
        // Monte Carlo check of the ≥99% match guarantee at d = 1e-8.
        let mut matches = 0;
        let trials = 200;
        for t in 0..trials {
            let v = random_values(100 + t, 4096);
            let mut w = v.clone();
            let per_elem = 1e-8f32 / (w.len() as f32).sqrt();
            for x in w.iter_mut() {
                *x += per_elem;
            }
            let a = LshSignature::of_values(&v);
            let b = LshSignature::of_values(&w);
            if a.buckets == b.buckets {
                matches += 1;
            }
        }
        // Allow slack below the theoretical 99%.
        assert!(matches >= trials * 95 / 100, "only {matches}/{trials} matched");
    }

    #[test]
    fn variable_length_inputs_hash_fine() {
        // The random pool supports any input size, including > POOL_SIZE.
        for n in [1usize, 7, 1000, POOL_SIZE, POOL_SIZE + 1, 3 * POOL_SIZE + 17] {
            let v = random_values(7, n);
            let sig = LshSignature::of_values(&v);
            assert!(sig.projections.iter().any(|&p| p != 0.0) || v.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn json_roundtrip() {
        let v = random_values(8, 1000);
        let sig = LshSignature::of_values(&v);
        let json = sig.to_json();
        let back = LshSignature::from_json(&json).unwrap();
        assert_eq!(sig.buckets, back.buckets);
        for j in 0..NUM_HASHES {
            assert!((sig.projections[j] - back.projections[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn shape_change_changes_projection() {
        // More values -> different projection (cyclic fold).
        let v = random_values(9, 2000);
        let mut w = v.clone();
        w.extend_from_slice(&[0.5, -0.5]);
        let a = LshSignature::of_values(&v);
        let b = LshSignature::of_values(&w);
        assert_eq!(a.compare(&b), LshVerdict::Changed);
    }
}
