//! Update plug-ins (paper §3.3 "Updates").
//!
//! An `Update` stores a parameter group as "the smallest amount of
//! information needed to describe how the parameter group was modified"
//! and can reconstruct the full values from that information plus (for
//! incremental types) the previous version of the group:
//!
//! * [`DenseUpdate`] — full values; terminates every chain.
//! * [`SparseUpdate`] — indices + new values of changed elements
//!   (Sung et al. 2021; Guo et al. 2021). Assignment semantics make
//!   reconstruction bit-exact.
//! * [`LowRankUpdate`] — LoRA-style factors A·B added to the base
//!   (Hu et al. 2022). Factors can be *inferred* from (prev, new) via
//!   early-abort Gram–Schmidt rank factorization, or supplied exactly
//!   by the trainer through [`UpdatePayload::low_rank_from_factors`]
//!   (the paper's "external file" path that avoids numerical mismatch).
//! * [`Ia3Update`] — per-column rescaling (Liu et al. 2022).
//! * [`TrimUpdate`] — row-prefix removal (the paper's final benchmark
//!   commit removes T5 sentinel embeddings and stores only which rows
//!   survive).
//!
//! Inference tries every registered type and keeps the cheapest
//! representation, so a LoRA-shaped delta never gets stored densely.

use crate::tensor::{allclose, Tensor};
use crate::util::json::{Json, JsonObj};
use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;
use std::collections::BTreeMap;
use std::sync::RwLock;

/// The data an update stores: named tensors + scalar extras.
#[derive(Debug, Clone, PartialEq)]
pub struct UpdatePayload {
    /// Update-type name this payload belongs to (e.g. "sparse").
    pub kind: String,
    /// Named tensors the update stores (e.g. `indices` + `values`).
    pub tensors: BTreeMap<String, Tensor>,
    /// Update-specific scalars (e.g. `{"alpha": 2.0}`).
    pub extra: Json,
}

impl UpdatePayload {
    /// An empty payload of the given update type.
    pub fn new(kind: &str) -> UpdatePayload {
        UpdatePayload {
            kind: kind.to_string(),
            tensors: BTreeMap::new(),
            extra: Json::Null,
        }
    }

    /// In-memory size of the stored tensors (serialization estimate).
    pub fn raw_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.nbytes()).sum::<usize>() + 64
    }

    /// Build an exact low-rank payload from trainer-provided factors:
    /// new = prev + (alpha / r) · A @ B, A: (m, r), B: (r, n).
    pub fn low_rank_from_factors(a: Tensor, b: Tensor, alpha: f32) -> Result<UpdatePayload> {
        if a.shape().len() != 2 || b.shape().len() != 2 || a.shape()[1] != b.shape()[0] {
            bail!(
                "low-rank factors must be (m,r) x (r,n); got {:?} x {:?}",
                a.shape(),
                b.shape()
            );
        }
        let mut p = UpdatePayload::new("low_rank");
        p.tensors.insert("a".into(), a);
        p.tensors.insert("b".into(), b);
        let mut extra = JsonObj::new();
        extra.insert("alpha", Json::Num(alpha as f64));
        p.extra = Json::Obj(extra);
        Ok(p)
    }
}

/// An update-type plug-in.
pub trait UpdateType: Send + Sync {
    /// Registry name of this update type.
    fn name(&self) -> &'static str;

    /// Does reconstruction require the previous value of the group?
    fn requires_prev(&self) -> bool;

    /// Try to express `new` as this update type on top of `prev`.
    /// Returns `None` when the type doesn't apply (wrong shape, no
    /// saving, pattern mismatch).
    fn infer(&self, prev: Option<&Tensor>, new: &Tensor) -> Result<Option<UpdatePayload>>;

    /// Reconstruct the full parameter values.
    fn apply(&self, payload: &UpdatePayload, prev: Option<&Tensor>) -> Result<Tensor>;
}

// ----------------------------------------------------------------------
// dense
// ----------------------------------------------------------------------

/// Full values; terminates every chain.
pub struct DenseUpdate;

impl UpdateType for DenseUpdate {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn requires_prev(&self) -> bool {
        false
    }

    fn infer(&self, _prev: Option<&Tensor>, new: &Tensor) -> Result<Option<UpdatePayload>> {
        let mut p = UpdatePayload::new("dense");
        p.tensors.insert("values".into(), new.clone());
        Ok(Some(p))
    }

    fn apply(&self, payload: &UpdatePayload, _prev: Option<&Tensor>) -> Result<Tensor> {
        payload
            .tensors
            .get("values")
            .cloned()
            .context("dense update missing 'values'")
    }
}

// ----------------------------------------------------------------------
// sparse
// ----------------------------------------------------------------------

/// Indices + new values of changed elements; bit-exact assignment
/// semantics on reconstruction.
pub struct SparseUpdate;

/// Store sparsely only when under this density (storage break-even for
/// i64 index + f32 value vs one f32 is 1/3; leave headroom).
const SPARSE_MAX_DENSITY: f64 = 0.25;

impl UpdateType for SparseUpdate {
    fn name(&self) -> &'static str {
        "sparse"
    }

    fn requires_prev(&self) -> bool {
        true
    }

    fn infer(&self, prev: Option<&Tensor>, new: &Tensor) -> Result<Option<UpdatePayload>> {
        let prev = match prev {
            Some(p) if p.shape() == new.shape() && p.dtype() == new.dtype() => p,
            _ => return Ok(None),
        };
        if !new.dtype().is_float() {
            return Ok(None);
        }
        let pv = prev.to_f32_vec()?;
        let nv = new.to_f32_vec()?;
        let max_nnz = (nv.len() as f64 * SPARSE_MAX_DENSITY) as usize;
        // Sampled precheck (§Perf): a full fine-tune changes everything,
        // so probing ~1k strided elements rejects dense changes without
        // scanning (and allocating indices for) a quarter of the tensor.
        if nv.len() > 4096 {
            let stride = (nv.len() / 1024).max(1);
            let mut sampled = 0usize;
            let mut changed = 0usize;
            let mut i = 0;
            while i < nv.len() {
                sampled += 1;
                if pv[i].to_bits() != nv[i].to_bits() {
                    changed += 1;
                }
                i += stride;
            }
            if changed as f64 > sampled as f64 * SPARSE_MAX_DENSITY * 1.5 {
                return Ok(None);
            }
        }
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, (p, n)) in pv.iter().zip(&nv).enumerate() {
            if p.to_bits() != n.to_bits() {
                if indices.len() >= max_nnz {
                    return Ok(None); // too dense to be worth it
                }
                indices.push(i as i64);
                values.push(*n);
            }
        }
        let mut payload = UpdatePayload::new("sparse");
        let nnz = indices.len();
        payload
            .tensors
            .insert("indices".into(), Tensor::from_i64(vec![nnz], indices)?);
        payload
            .tensors
            .insert("values".into(), Tensor::from_f32(vec![nnz], values)?);
        Ok(Some(payload))
    }

    fn apply(&self, payload: &UpdatePayload, prev: Option<&Tensor>) -> Result<Tensor> {
        let prev = prev.context("sparse update requires previous value")?;
        let indices = payload
            .tensors
            .get("indices")
            .context("sparse update missing 'indices'")?
            .to_i64_vec()?;
        let values = payload
            .tensors
            .get("values")
            .context("sparse update missing 'values'")?
            .to_f32_vec()?;
        if indices.len() != values.len() {
            bail!("sparse update index/value length mismatch");
        }
        let mut out = prev.to_f32_vec()?;
        for (&i, &v) in indices.iter().zip(&values) {
            let i = i as usize;
            if i >= out.len() {
                bail!("sparse index {i} out of bounds ({})", out.len());
            }
            out[i] = v; // assignment semantics: bit-exact reconstruction
        }
        Ok(Tensor::from_f32_as(prev.dtype(), prev.shape().to_vec(), &out)?)
    }
}

// ----------------------------------------------------------------------
// low-rank
// ----------------------------------------------------------------------

/// LoRA-style additive low-rank factors A·B on top of the base.
pub struct LowRankUpdate;

impl UpdateType for LowRankUpdate {
    fn name(&self) -> &'static str {
        "low_rank"
    }

    fn requires_prev(&self) -> bool {
        true
    }

    fn infer(&self, prev: Option<&Tensor>, new: &Tensor) -> Result<Option<UpdatePayload>> {
        let prev = match prev {
            Some(p) if p.shape() == new.shape() && new.shape().len() == 2 => p,
            _ => return Ok(None),
        };
        if !new.dtype().is_float() {
            return Ok(None);
        }
        let (m, n) = (new.shape()[0], new.shape()[1]);
        // Rank cap that guarantees ≥4x storage saving: r(m+n) ≤ mn/4.
        let max_rank = (m * n) / (4 * (m + n));
        if max_rank == 0 {
            return Ok(None);
        }
        let pv = prev.to_f32_vec()?;
        let nv = new.to_f32_vec()?;
        let delta: Vec<f64> = nv
            .iter()
            .zip(&pv)
            .map(|(a, b)| *a as f64 - *b as f64)
            .collect();

        // Residual tolerance: rows whose residual is below both a
        // relative threshold and the f32 rounding floor of `new` are
        // treated as dependent. The floor matters because the delta of a
        // LoRA-merged f32 checkpoint is only rank-r up to rounding noise.
        let max_abs = nv.iter().fold(0f64, |m, &v| m.max(v.abs() as f64));
        let noise_floor = max_abs * 1.2e-7 * (n as f64).sqrt() * 8.0;
        let factors = match rank_factorize(&delta, m, n, max_rank, noise_floor) {
            Some(f) => f,
            None => return Ok(None),
        };
        let (a, b, r) = factors;

        // Exactness guard: accept only if prev + A·B reconstructs `new`
        // within the f32 rounding noise of the factorization (paper:
        // inference "can introduce numerical noise"; exact factors can
        // always be supplied via `low_rank_from_factors` instead).
        let recon = apply_low_rank(prev, &a, &b, m, n, r, 1.0)?;
        // Consistent with the factorization: a dropped (dependent) row
        // may leave up to `noise_floor` residual, so that is the
        // per-element bound the reconstruction is held to.
        let atol = noise_floor.max(1e-8);
        if !allclose(&recon, new, 1e-5, atol)? {
            return Ok(None);
        }

        let mut payload = UpdatePayload::new("low_rank");
        payload.tensors.insert(
            "a".into(),
            Tensor::from_f32(vec![m, r], a.iter().map(|&x| x as f32).collect())?,
        );
        payload.tensors.insert(
            "b".into(),
            Tensor::from_f32(vec![r, n], b.iter().map(|&x| x as f32).collect())?,
        );
        let mut extra = JsonObj::new();
        extra.insert("alpha", Json::Num(1.0));
        payload.extra = Json::Obj(extra);
        Ok(Some(payload))
    }

    fn apply(&self, payload: &UpdatePayload, prev: Option<&Tensor>) -> Result<Tensor> {
        let prev = prev.context("low-rank update requires previous value")?;
        let a = payload
            .tensors
            .get("a")
            .context("low-rank update missing 'a'")?;
        let b = payload
            .tensors
            .get("b")
            .context("low-rank update missing 'b'")?;
        let alpha = payload
            .extra
            .get("alpha")
            .and_then(|v| v.as_f64())
            .unwrap_or(1.0) as f32;
        let (m, r) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        if b.shape()[0] != r || prev.shape() != [m, n] {
            bail!(
                "low-rank shape mismatch: prev {:?}, a {:?}, b {:?}",
                prev.shape(),
                a.shape(),
                b.shape()
            );
        }
        let av: Vec<f64> = a.to_f32_vec()?.iter().map(|&x| x as f64).collect();
        let bv: Vec<f64> = b.to_f32_vec()?.iter().map(|&x| x as f64).collect();
        let scale = if r > 0 { alpha as f64 } else { 0.0 };
        apply_low_rank_scaled(prev, &av, &bv, m, n, r, scale)
    }
}

fn apply_low_rank(
    prev: &Tensor,
    a: &[f64],
    b: &[f64],
    m: usize,
    n: usize,
    r: usize,
    scale: f64,
) -> Result<Tensor> {
    apply_low_rank_scaled(prev, a, b, m, n, r, scale)
}

fn apply_low_rank_scaled(
    prev: &Tensor,
    a: &[f64],
    b: &[f64],
    m: usize,
    n: usize,
    r: usize,
    scale: f64,
) -> Result<Tensor> {
    let pv = prev.to_f32_vec()?;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * r..(i + 1) * r];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let mut acc = 0f64;
            for (k, &ak) in arow.iter().enumerate() {
                acc += ak * b[k * n + j];
            }
            *o = (pv[i * n + j] as f64 + scale * acc) as f32;
        }
    }
    Ok(Tensor::from_f32_as(prev.dtype(), prev.shape().to_vec(), &out)?)
}

/// Early-abort rank factorization of an m×n matrix via row-space
/// Gram–Schmidt. Returns (A: m×r, B: r×n) with delta ≈ A·B, or None if
/// the rank exceeds `max_rank` (cost until abort is O(max_rank²·n)).
fn rank_factorize(
    delta: &[f64],
    m: usize,
    n: usize,
    max_rank: usize,
    noise_floor: f64,
) -> Option<(Vec<f64>, Vec<f64>, usize)> {
    let frob: f64 = delta.iter().map(|x| x * x).sum::<f64>().sqrt();
    if frob == 0.0 {
        return Some((vec![0.0; 0], vec![0.0; 0], 0));
    }
    // Per-row residual threshold: relative to the average row norm, but
    // never below the caller's floating-point noise floor.
    let tol = ((frob / (m as f64).sqrt()) * 1e-5).max(noise_floor);
    let mut basis: Vec<f64> = Vec::new(); // r rows of length n, orthonormal
    let mut coeffs: Vec<Vec<f64>> = Vec::new(); // per input row, r coefficients

    for i in 0..m {
        let row = &delta[i * n..(i + 1) * n];
        let mut resid = row.to_vec();
        let r = basis.len() / n.max(1);
        let mut c = vec![0f64; r];
        for k in 0..r {
            let q = &basis[k * n..(k + 1) * n];
            let dot: f64 = resid.iter().zip(q).map(|(x, y)| x * y).sum();
            c[k] = dot;
            for (x, y) in resid.iter_mut().zip(q) {
                *x -= dot * y;
            }
        }
        let rnorm: f64 = resid.iter().map(|x| x * x).sum::<f64>().sqrt();
        if rnorm > tol {
            if basis.len() / n.max(1) >= max_rank {
                return None; // rank too high; not worth storing low-rank
            }
            for x in resid.iter_mut() {
                *x /= rnorm;
            }
            basis.extend_from_slice(&resid);
            c.push(rnorm);
        }
        coeffs.push(c);
    }

    let r = basis.len() / n.max(1);
    let mut a = vec![0f64; m * r];
    for (i, c) in coeffs.iter().enumerate() {
        a[i * r..i * r + c.len()].copy_from_slice(c);
    }
    Some((a, basis, r))
}

// ----------------------------------------------------------------------
// IA3 (per-column rescaling)
// ----------------------------------------------------------------------

/// IA3-style per-column rescaling (Liu et al. 2022).
pub struct Ia3Update;

impl UpdateType for Ia3Update {
    fn name(&self) -> &'static str {
        "ia3"
    }

    fn requires_prev(&self) -> bool {
        true
    }

    fn infer(&self, prev: Option<&Tensor>, new: &Tensor) -> Result<Option<UpdatePayload>> {
        let prev = match prev {
            Some(p) if p.shape() == new.shape() && new.shape().len() == 2 => p,
            _ => return Ok(None),
        };
        if !new.dtype().is_float() {
            return Ok(None);
        }
        let (m, n) = (new.shape()[0], new.shape()[1]);
        if m < 2 {
            return Ok(None); // a 1-row matrix is better stored densely
        }
        let pv = prev.to_f32_vec()?;
        let nv = new.to_f32_vec()?;
        // Recover s[j] from the first row with a nonzero entry, then
        // verify exact recomputation everywhere.
        let mut scale = vec![1f32; n];
        for j in 0..n {
            let mut found = false;
            for i in 0..m {
                let p = pv[i * n + j];
                if p != 0.0 {
                    scale[j] = nv[i * n + j] / p;
                    found = true;
                    break;
                }
            }
            if !found && nv.iter().skip(j).step_by(n).any(|&v| v != 0.0) {
                return Ok(None); // zero column became nonzero: not a rescale
            }
        }
        // Verify the rescale reproduces `new` to f32 rounding noise
        // (recovered ratios are one division away from the trainer's
        // multiply, so exact bit equality is too strict; the paper
        // accepts inference-induced rounding noise).
        for i in 0..m {
            for j in 0..n {
                let recon = pv[i * n + j] * scale[j];
                let target = nv[i * n + j];
                let tol = 4.0 * f32::EPSILON * target.abs().max(pv[i * n + j].abs());
                if (recon - target).abs() > tol {
                    return Ok(None);
                }
            }
        }
        let mut payload = UpdatePayload::new("ia3");
        payload
            .tensors
            .insert("scale".into(), Tensor::from_f32(vec![n], scale)?);
        Ok(Some(payload))
    }

    fn apply(&self, payload: &UpdatePayload, prev: Option<&Tensor>) -> Result<Tensor> {
        let prev = prev.context("ia3 update requires previous value")?;
        let scale = payload
            .tensors
            .get("scale")
            .context("ia3 update missing 'scale'")?
            .to_f32_vec()?;
        if prev.shape().len() != 2 || prev.shape()[1] != scale.len() {
            bail!(
                "ia3 shape mismatch: prev {:?}, scale len {}",
                prev.shape(),
                scale.len()
            );
        }
        let (m, n) = (prev.shape()[0], prev.shape()[1]);
        let pv = prev.to_f32_vec()?;
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] = pv[i * n + j] * scale[j];
            }
        }
        Ok(Tensor::from_f32_as(prev.dtype(), prev.shape().to_vec(), &out)?)
    }
}

// ----------------------------------------------------------------------
// trim (row-prefix removal)
// ----------------------------------------------------------------------

/// Row-prefix removal: stores only how many rows survive.
pub struct TrimUpdate;

impl UpdateType for TrimUpdate {
    fn name(&self) -> &'static str {
        "trim"
    }

    fn requires_prev(&self) -> bool {
        true
    }

    fn infer(&self, prev: Option<&Tensor>, new: &Tensor) -> Result<Option<UpdatePayload>> {
        let prev = match prev {
            Some(p) => p,
            None => return Ok(None),
        };
        if prev.dtype() != new.dtype()
            || prev.shape().len() != new.shape().len()
            || prev.shape().is_empty()
            || prev.shape()[1..] != new.shape()[1..]
            || new.shape()[0] >= prev.shape()[0]
        {
            return Ok(None);
        }
        let keep = new.shape()[0];
        let trimmed = prev.take_rows(keep)?;
        if trimmed.bytes() != new.bytes() {
            return Ok(None);
        }
        let mut payload = UpdatePayload::new("trim");
        let mut extra = JsonObj::new();
        extra.insert("keep", keep);
        payload.extra = Json::Obj(extra);
        Ok(Some(payload))
    }

    fn apply(&self, payload: &UpdatePayload, prev: Option<&Tensor>) -> Result<Tensor> {
        let prev = prev.context("trim update requires previous value")?;
        let keep = payload
            .extra
            .get("keep")
            .and_then(|v| v.as_usize())
            .context("trim update missing 'keep'")?;
        prev.take_rows(keep).context("trim apply")
    }
}

// ----------------------------------------------------------------------
// registry + auto-inference
// ----------------------------------------------------------------------

static REGISTRY: Lazy<RwLock<Vec<&'static dyn UpdateType>>> = Lazy::new(|| {
    RwLock::new(vec![
        &TrimUpdate as &'static dyn UpdateType,
        &Ia3Update,
        &SparseUpdate,
        &LowRankUpdate,
        &DenseUpdate,
    ])
});

/// Register a user update-type plug-in (tried before `dense`).
pub fn register_update_type(u: Box<dyn UpdateType>) {
    let u: &'static dyn UpdateType = Box::leak(u);
    let mut reg = REGISTRY.write().unwrap();
    let dense_pos = reg.iter().position(|t| t.name() == "dense").unwrap_or(0);
    reg.insert(dense_pos, u);
}

/// Look up an update type by name.
pub fn update_type(name: &str) -> Option<&'static dyn UpdateType> {
    REGISTRY.read().unwrap().iter().copied().find(|u| u.name() == name)
}

/// Names of registered update types, in trial order.
pub fn update_type_names() -> Vec<&'static str> {
    REGISTRY.read().unwrap().iter().map(|u| u.name()).collect()
}

/// Infer the cheapest representation of `new` given `prev`.
///
/// `forced` pins a specific type (the paper's per-file/user override);
/// otherwise every registered type is tried and the smallest payload
/// wins (dense always succeeds, so this never fails).
pub fn infer_best(
    prev: Option<&Tensor>,
    new: &Tensor,
    forced: Option<&str>,
) -> Result<UpdatePayload> {
    if let Some(name) = forced {
        let u = update_type(name).with_context(|| format!("unknown update type '{name}'"))?;
        return u
            .infer(prev, new)?
            .with_context(|| format!("update type '{name}' cannot represent this change"));
    }
    let mut best: Option<UpdatePayload> = None;
    for u in REGISTRY.read().unwrap().iter() {
        if let Some(p) = u.infer(prev, new)? {
            if best.as_ref().map_or(true, |b| p.raw_bytes() < b.raw_bytes()) {
                best = Some(p);
            }
        }
    }
    best.context("no update type could represent this tensor (dense should always apply)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_matrix(seed: u64, m: usize, n: usize) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let vals: Vec<f32> = (0..m * n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
        Tensor::from_f32(vec![m, n], vals).unwrap()
    }

    #[test]
    fn dense_roundtrip() {
        let t = random_matrix(1, 8, 8);
        let p = DenseUpdate.infer(None, &t).unwrap().unwrap();
        assert_eq!(DenseUpdate.apply(&p, None).unwrap(), t);
    }

    #[test]
    fn sparse_exact_roundtrip() {
        let prev = random_matrix(2, 32, 32);
        let mut nv = prev.to_f32_vec().unwrap();
        nv[5] = 7.25;
        nv[100] = -1.5;
        nv[1000] += 0.125;
        let new = Tensor::from_f32(vec![32, 32], nv).unwrap();
        let p = SparseUpdate.infer(Some(&prev), &new).unwrap().unwrap();
        assert_eq!(p.tensors["indices"].numel(), 3);
        let recon = SparseUpdate.apply(&p, Some(&prev)).unwrap();
        assert_eq!(recon, new); // bit-exact
    }

    #[test]
    fn sparse_rejects_dense_change() {
        let prev = random_matrix(3, 16, 16);
        let new = random_matrix(4, 16, 16); // everything changed
        assert!(SparseUpdate.infer(Some(&prev), &new).unwrap().is_none());
    }

    #[test]
    fn sparse_rejects_shape_change() {
        let prev = random_matrix(5, 4, 4);
        let new = random_matrix(5, 2, 8);
        assert!(SparseUpdate.infer(Some(&prev), &new).unwrap().is_none());
    }

    #[test]
    fn low_rank_infer_recovers_lora_delta() {
        let prev = random_matrix(6, 64, 48);
        // Build an exactly rank-2 delta in f64 then round to f32 once.
        let mut rng = Pcg64::new(7);
        let a: Vec<f64> = (0..64 * 2).map(|_| rng.next_gaussian() * 0.01).collect();
        let b: Vec<f64> = (0..2 * 48).map(|_| rng.next_gaussian() * 0.01).collect();
        let pv = prev.to_f32_vec().unwrap();
        let mut nv = vec![0f32; 64 * 48];
        for i in 0..64 {
            for j in 0..48 {
                let mut acc = 0f64;
                for k in 0..2 {
                    acc += a[i * 2 + k] * b[k * 48 + j];
                }
                nv[i * 48 + j] = (pv[i * 48 + j] as f64 + acc) as f32;
            }
        }
        let new = Tensor::from_f32(vec![64, 48], nv).unwrap();
        let p = LowRankUpdate.infer(Some(&prev), &new).unwrap().unwrap();
        let r = p.tensors["a"].shape()[1];
        assert!(r <= 3, "recovered rank {r}");
        let recon = LowRankUpdate.apply(&p, Some(&prev)).unwrap();
        assert!(allclose(&recon, &new, 1e-5, 1e-7).unwrap());
        // Storage is much smaller than dense.
        assert!(p.raw_bytes() < new.nbytes() / 4);
    }

    #[test]
    fn low_rank_rejects_full_rank_delta() {
        let prev = random_matrix(8, 32, 32);
        let new = random_matrix(9, 32, 32);
        assert!(LowRankUpdate.infer(Some(&prev), &new).unwrap().is_none());
    }

    #[test]
    fn low_rank_from_factors_applies_with_alpha() {
        let prev = random_matrix(10, 8, 6);
        let a = Tensor::from_f32(vec![8, 1], vec![1.0; 8]).unwrap();
        let b = Tensor::from_f32(vec![1, 6], vec![0.5; 6]).unwrap();
        let p = UpdatePayload::low_rank_from_factors(a, b, 2.0).unwrap();
        let out = LowRankUpdate.apply(&p, Some(&prev)).unwrap();
        let pv = prev.to_f32_vec().unwrap();
        let ov = out.to_f32_vec().unwrap();
        for (o, p) in ov.iter().zip(&pv) {
            assert!((o - (p + 1.0)).abs() < 1e-6); // 2.0 * 1.0 * 0.5
        }
    }

    #[test]
    fn ia3_infer_and_apply() {
        let prev = random_matrix(11, 16, 8);
        let scale: Vec<f32> = (0..8).map(|j| 1.0 + j as f32 * 0.1).collect();
        let pv = prev.to_f32_vec().unwrap();
        let nv: Vec<f32> = pv
            .iter()
            .enumerate()
            .map(|(idx, &v)| v * scale[idx % 8])
            .collect();
        let new = Tensor::from_f32(vec![16, 8], nv).unwrap();
        let p = Ia3Update.infer(Some(&prev), &new).unwrap().unwrap();
        assert_eq!(p.tensors["scale"].numel(), 8);
        assert_eq!(Ia3Update.apply(&p, Some(&prev)).unwrap(), new);
    }

    #[test]
    fn ia3_rejects_non_rescale() {
        let prev = random_matrix(12, 8, 8);
        let mut nv = prev.to_f32_vec().unwrap();
        nv[3] += 1.0;
        let new = Tensor::from_f32(vec![8, 8], nv).unwrap();
        assert!(Ia3Update.infer(Some(&prev), &new).unwrap().is_none());
    }

    #[test]
    fn trim_infer_and_apply() {
        let prev = random_matrix(13, 100, 16);
        let new = prev.take_rows(90).unwrap();
        let p = TrimUpdate.infer(Some(&prev), &new).unwrap().unwrap();
        assert!(p.tensors.is_empty()); // nearly free to store
        assert_eq!(TrimUpdate.apply(&p, Some(&prev)).unwrap(), new);
    }

    #[test]
    fn trim_rejects_modified_prefix() {
        let prev = random_matrix(14, 10, 4);
        let mut t = prev.take_rows(8).unwrap().to_f32_vec().unwrap();
        t[0] += 1.0;
        let new = Tensor::from_f32(vec![8, 4], t).unwrap();
        assert!(TrimUpdate.infer(Some(&prev), &new).unwrap().is_none());
    }

    #[test]
    fn infer_best_picks_cheapest() {
        let prev = random_matrix(15, 64, 64);
        // Sparse change of 3 elements -> sparse wins.
        let mut nv = prev.to_f32_vec().unwrap();
        nv[0] = 9.0;
        let new = Tensor::from_f32(vec![64, 64], nv).unwrap();
        let p = infer_best(Some(&prev), &new, None).unwrap();
        assert_eq!(p.kind, "sparse");
        // Trim wins over everything.
        let trimmed = prev.take_rows(32).unwrap();
        let p = infer_best(Some(&prev), &trimmed, None).unwrap();
        assert_eq!(p.kind, "trim");
        // No prev -> dense.
        let p = infer_best(None, &new, None).unwrap();
        assert_eq!(p.kind, "dense");
        // Forced dense works regardless.
        let p = infer_best(Some(&prev), &new, Some("dense")).unwrap();
        assert_eq!(p.kind, "dense");
    }

    #[test]
    fn registry_lookup_and_names() {
        assert!(update_type("dense").is_some());
        assert!(update_type("sparse").is_some());
        assert!(update_type("low_rank").is_some());
        assert!(update_type("ia3").is_some());
        assert!(update_type("trim").is_some());
        assert!(update_type("bogus").is_none());
        let names = update_type_names();
        assert_eq!(names.last(), Some(&"dense"));
    }
}
