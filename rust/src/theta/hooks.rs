//! Git-Theta repository hooks (paper §3.2 "Committing a Model",
//! "Pushing a Model to a Remote").
//!
//! * **post-commit**: scan the new commit for model metadata files and
//!   record the LFS objects introduced by that commit in
//!   `.theta/commits/<commit>` (the paper's `.git/theta/commits/`).
//! * **pre-push**: union the recorded objects for every commit being
//!   pushed and batch-upload them to the remote's LFS store.

use crate::gitcore::drivers::Hooks;
use crate::gitcore::object::{Oid, Tree};
use crate::gitcore::remote::RemoteSpec;
use crate::gitcore::repo::Repository;
use crate::lfs::{transport, LfsStore, Pointer};
use crate::theta::metadata::ModelMetadata;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Git-Theta's repository [`Hooks`] implementation: records each
/// commit's newly introduced LFS objects (post-commit) and batch-syncs
/// the union of pushed commits' objects to the remote (pre-push).
pub struct ThetaHooks;

fn commits_dir(repo: &Repository) -> PathBuf {
    repo.theta_dir().join("commits")
}

/// Every LFS oid a tree's blobs reference — model-metadata chains and
/// plain LFS pointer files alike. Used by `git-theta fetch` to prefetch
/// a revision's full object closure in one pack.
pub fn referenced_lfs_oids(repo: &Repository, tree: &Tree) -> Result<Vec<Oid>> {
    let mut oids = Vec::new();
    for entry in &tree.entries {
        let blob = repo.odb().read_blob(&entry.oid)?;
        if ModelMetadata::is_metadata(&blob) {
            // The sniffer can match lookalikes (ordinary JSON mentioning
            // "git-theta", or future metadata versions). A read-side
            // prefetch must not abort on them — their objects simply
            // stay lazy.
            if let Ok(meta) = ModelMetadata::from_bytes(&blob) {
                oids.extend(meta.all_oids());
            }
        } else {
            oids.extend(Pointer::oid_of_blob(&blob));
        }
    }
    oids.sort();
    oids.dedup();
    Ok(oids)
}

/// Compute the LFS oids introduced by `commit` (vs its first parent).
pub fn new_objects_of_commit(repo: &Repository, commit: &Oid) -> Result<Vec<Oid>> {
    let c = repo.odb().read_commit(commit)?;
    let tree = repo.odb().read_tree(&c.tree)?;
    let parent_tree = match c.parents.first() {
        Some(p) => Some(repo.odb().read_tree(&repo.odb().read_commit(p)?.tree)?),
        None => None,
    };
    let mut oids = Vec::new();
    for entry in &tree.entries {
        let blob = repo.odb().read_blob(&entry.oid)?;
        if !ModelMetadata::is_metadata(&blob) {
            continue;
        }
        let meta = ModelMetadata::from_bytes(&blob)
            .with_context(|| format!("metadata file '{}'", entry.path))?;
        let prev = match parent_tree.as_ref().and_then(|t| t.get(&entry.path)) {
            Some(prev_oid) if prev_oid != entry.oid => {
                let prev_blob = repo.odb().read_blob(&prev_oid)?;
                if ModelMetadata::is_metadata(&prev_blob) {
                    Some(ModelMetadata::from_bytes(&prev_blob)?)
                } else {
                    None
                }
            }
            Some(_) => Some(meta.clone()), // unchanged: no new objects
            None => None,
        };
        oids.extend(meta.new_oids_vs(prev.as_ref()));
    }
    oids.sort();
    oids.dedup();
    Ok(oids)
}

/// Read the recorded object list for a commit, recomputing if absent
/// (e.g. for commits created before Git-Theta was installed).
pub fn objects_of_commit(repo: &Repository, commit: &Oid) -> Result<Vec<Oid>> {
    let path = commits_dir(repo).join(commit.to_hex());
    if path.exists() {
        let json = Json::parse(&std::fs::read_to_string(&path)?)
            .context("parsing .theta/commits entry")?;
        let arr = json
            .get("objects")
            .and_then(|v| v.as_arr())
            .context("commits entry missing objects")?;
        return arr
            .iter()
            .map(|v| Oid::from_hex(v.as_str().context("bad oid")?))
            .collect();
    }
    new_objects_of_commit(repo, commit)
}

impl Hooks for ThetaHooks {
    fn post_commit(&self, repo: &Repository, commit: &Oid) -> Result<()> {
        let oids = new_objects_of_commit(repo, commit)?;
        let dir = commits_dir(repo);
        std::fs::create_dir_all(&dir)?;
        let mut root = crate::util::json::JsonObj::new();
        root.insert(
            "objects",
            Json::Arr(oids.iter().map(|o| Json::from(o.to_hex())).collect()),
        );
        std::fs::write(
            dir.join(commit.to_hex()),
            Json::Obj(root).to_string_pretty(),
        )
        .context("writing .theta/commits entry")
    }

    fn pre_push(&self, repo: &Repository, remote: &RemoteSpec, commits: &[Oid]) -> Result<()> {
        let store = LfsStore::open(repo.theta_dir());
        let mut oids = Vec::new();
        for commit in commits {
            oids.extend(objects_of_commit(repo, commit)?);
        }
        oids.sort();
        oids.dedup();
        // Only objects we hold locally; metadata-referenced objects from
        // shallow histories we never materialized can't be pushed.
        let have: Vec<Oid> = oids.into_iter().filter(|o| store.contains(o)).collect();
        let adv = transport::ChainAdvert {
            chains: chain_adverts(repo, commits)?,
            want: have,
        };
        let remote = transport::open_transport(remote, Some(repo.theta_dir()))?;
        transport::upload_with_chains(&store, remote.as_ref(), &adv)?;
        Ok(())
    }
}

/// Collect the incremental chains (depth ≥ 2) referenced by the pushed
/// commits' metadata files, as wire adverts. A chain-aware remote that
/// already holds a prefix of one answers with its depth, and the push
/// ships the suffix as deltas against the deepest held entry. Commits
/// with no model metadata yield no chains, which keeps their pushes on
/// the exact flat (protocol-1) path.
fn chain_adverts(
    repo: &Repository,
    commits: &[Oid],
) -> Result<Vec<Vec<transport::ChainEntryAdvert>>> {
    let mut seen_tips = std::collections::HashSet::new();
    let mut chains = Vec::new();
    for commit in commits {
        let c = repo.odb().read_commit(commit)?;
        let tree = repo.odb().read_tree(&c.tree)?;
        tree_chain_adverts(repo, &tree, &mut seen_tips, &mut chains)?;
    }
    Ok(chains)
}

/// Append the chain adverts one tree's metadata files reference,
/// deduped by tip key across calls (the same chain appears in every
/// commit — and every metadata file — that carries the group forward
/// unchanged).
fn tree_chain_adverts(
    repo: &Repository,
    tree: &Tree,
    seen_tips: &mut std::collections::HashSet<Oid>,
    chains: &mut Vec<Vec<transport::ChainEntryAdvert>>,
) -> Result<()> {
    for entry in &tree.entries {
        let blob = repo.odb().read_blob(&entry.oid)?;
        if !ModelMetadata::is_metadata(&blob) {
            continue;
        }
        let Ok(meta) = ModelMetadata::from_bytes(&blob) else {
            continue;
        };
        meta_chain_adverts(&meta, seen_tips, chains);
    }
    Ok(())
}

/// Append the chain adverts (depth ≥ 2) one metadata file records.
/// Shallower groups stay off the advert: a depth-1 chain has no prefix
/// a peer could hold, so advertising it would only bloat the
/// negotiation body.
pub(crate) fn meta_chain_adverts(
    meta: &ModelMetadata,
    seen_tips: &mut std::collections::HashSet<Oid>,
    chains: &mut Vec<Vec<transport::ChainEntryAdvert>>,
) {
    for group in meta.groups.values() {
        if group.chain_depth() < 2 {
            continue;
        }
        let entries = group.chain_entries();
        let Some((tip_key, _)) = entries.last() else {
            continue;
        };
        if !seen_tips.insert(*tip_key) {
            continue;
        }
        chains.push(
            entries
                .into_iter()
                .map(|(key, oids)| transport::ChainEntryAdvert { key, oids })
                .collect(),
        );
    }
}

/// The chain advert a fetch of `tree` should send: every LFS oid the
/// tree references as the want set, plus the update chains its
/// metadata records. The transfer layer trims the want set to locally
/// missing oids before the advert leaves the process — which is
/// exactly what lets the responder read this client's held chain
/// depths straight off the advert (an entry whose oids are all outside
/// the want set is provably held here) and ship the wanted suffix as
/// deltas against bases this clone already has.
pub fn fetch_advert(repo: &Repository, tree: &Tree) -> Result<transport::ChainAdvert> {
    let mut seen_tips = std::collections::HashSet::new();
    let mut chains = Vec::new();
    tree_chain_adverts(repo, tree, &mut seen_tips, &mut chains)?;
    Ok(transport::ChainAdvert {
        chains,
        want: referenced_lfs_oids(repo, tree)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{Checkpoint, SafetensorsFormat};
    use crate::gitcore::attributes::Attributes;
    use crate::tensor::Tensor;
    use crate::util::tmp::TempDir;

    fn setup_repo() -> (TempDir, Repository) {
        crate::init();
        let td = TempDir::new("thooks").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        Attributes::add_line(
            repo.worktree(),
            "*.safetensors filter=theta diff=theta merge=theta",
        )
        .unwrap();
        (td, repo)
    }

    fn write_ck(td: &TempDir, w: Vec<f32>) {
        use crate::checkpoint::CheckpointFormat;
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![w.len()], w).unwrap());
        SafetensorsFormat
            .save_file(&ck, &td.join("model.safetensors"))
            .unwrap();
    }

    #[test]
    fn post_commit_records_new_objects_only() {
        let (td, repo) = setup_repo();
        write_ck(&td, vec![1.0; 100]);
        repo.add(&["model.safetensors", ".thetaattributes"]).unwrap();
        let c1 = repo.commit("v1", "t").unwrap();
        let objs1 = objects_of_commit(&repo, &c1).unwrap();
        assert_eq!(objs1.len(), 1); // one dense group

        // Sparse change -> exactly one new object recorded.
        let mut w = vec![1.0f32; 100];
        w[3] = 9.0;
        write_ck(&td, w);
        repo.add(&["model.safetensors"]).unwrap();
        let c2 = repo.commit("v2", "t").unwrap();
        let objs2 = objects_of_commit(&repo, &c2).unwrap();
        assert_eq!(objs2.len(), 1);
        assert_ne!(objs1, objs2);
        // The record file exists on disk.
        assert!(td
            .path()
            .join(".theta/commits")
            .join(c2.to_hex())
            .exists());
    }

    #[test]
    fn pre_push_syncs_only_referenced_objects() {
        let (td, repo) = setup_repo();
        let td_remote = TempDir::new("thooks-remote").unwrap();
        write_ck(&td, vec![2.0; 50]);
        repo.add(&["model.safetensors", ".thetaattributes"]).unwrap();
        repo.commit("v1", "t").unwrap();
        repo.push(td_remote.path(), "main").unwrap();

        let remote_store = LfsStore::at(&td_remote.path().join("lfs/objects"));
        let local_store = LfsStore::open(repo.theta_dir());
        assert_eq!(
            remote_store.list().unwrap().len(),
            local_store.list().unwrap().len()
        );

        // Pushing again transfers nothing new.
        let before = remote_store.disk_usage().unwrap();
        repo.push(td_remote.path(), "main").unwrap();
        assert_eq!(remote_store.disk_usage().unwrap(), before);
    }
}
