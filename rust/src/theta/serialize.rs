//! Serializer plug-ins (paper §3.3 "Serialization").
//!
//! The paper serializes parameter-group tensors with TensorStore, whose
//! chunked, compressed layout is what makes even full dense commits
//! smaller than raw checkpoints (Table 1: T0-3B is distributed as an
//! f32 checkpoint holding bf16-trained values, which compresses ~2×).
//! [`TensorStoreSerializer`] reproduces that architecture: fixed-size
//! chunks, an optional byte-shuffle filter that groups the i-th byte of
//! every element together (turning the all-zero low-mantissa bytes of
//! bf16-valued f32 data into long runs), and zstd per chunk, compressed
//! in parallel.
//!
//! Decode is **in-place**: the final tensor buffer is allocated once,
//! and because chunks have a fixed pre-compression size, chunk `i`'s
//! bytes land at offset `i * chunk` — each worker decompresses straight
//! into its disjoint slice (`zstd::bulk::decompress_to_buffer`), with
//! [`byte_unshuffle_into`] fused into that scatter write. Peak
//! transient allocation is one chunk-sized scratch per worker (only
//! when shuffling), not a whole-tensor-capacity `Vec` per chunk plus a
//! final copy as in the copying path (kept behind
//! [`set_legacy_decode`] as the benchmark baseline).
//!
//! Multi-tensor updates (e.g. sparse = indices + values) are combined
//! into one blob with msgpack, as in the paper.

use crate::tensor::{DType, Tensor};
use crate::util::msgpack::Mp;
use crate::util::par;
use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::RwLock;

/// A tensor serializer plug-in.
pub trait Serializer: Send + Sync {
    /// Registry name of this serializer.
    fn name(&self) -> &'static str;
    /// Encode a tensor into a self-describing byte blob.
    fn serialize(&self, t: &Tensor) -> Result<Vec<u8>>;
    /// Decode a blob produced by [`Serializer::serialize`].
    fn deserialize(&self, bytes: &[u8]) -> Result<Tensor>;
}

/// Chunked + byte-shuffled + zstd-compressed serializer.
pub struct TensorStoreSerializer {
    /// Chunk size in bytes (pre-compression).
    pub chunk_bytes: usize,
    /// zstd level (1..=19).
    pub level: i32,
    /// Apply the byte-shuffle filter to float dtypes.
    pub shuffle: bool,
}

impl Default for TensorStoreSerializer {
    fn default() -> Self {
        TensorStoreSerializer {
            chunk_bytes: 4 << 20,
            level: 3,
            shuffle: true,
        }
    }
}

const TS_MAGIC: &[u8; 4] = b"TST1";

/// Process-wide decode-path toggle for the `bench checkout` ablation:
/// `true` selects the legacy copying decode (per-chunk `Vec` + final
/// assembly loop) instead of the in-place scatter decode.
static LEGACY_DECODE: AtomicBool = AtomicBool::new(false);

/// Select the copying decode path (`true`) or the default in-place
/// path (`false`). Benchmark-only; both paths produce identical
/// tensors.
pub fn set_legacy_decode(on: bool) {
    LEGACY_DECODE.store(on, Ordering::Relaxed);
}

/// Whether the legacy copying decode path is selected.
pub fn legacy_decode() -> bool {
    LEGACY_DECODE.load(Ordering::Relaxed)
}

thread_local! {
    // Per-worker scratch reused across chunks: shuffled input on the
    // serialize side, decompressed-but-shuffled output on the decode
    // side. Holds at most one chunk (default 4 MiB), trading that
    // residency for zero steady-state allocations in the hot loops.
    static CHUNK_SCRATCH: RefCell<Vec<u8>> = RefCell::new(Vec::new());
}

/// Parallelism heuristic shared by both directions: only tensors big
/// enough to matter get threads; the clean filter already parallelizes
/// across parameter groups, and nested pools hurt (§Perf).
fn chunk_threads(total_bytes: usize) -> usize {
    if total_bytes >= 16 << 20 {
        par::default_threads()
    } else {
        1
    }
}

impl Serializer for TensorStoreSerializer {
    fn name(&self) -> &'static str {
        "tensorstore"
    }

    fn serialize(&self, t: &Tensor) -> Result<Vec<u8>> {
        let use_shuffle = self.shuffle && t.dtype().is_float();
        let elem = t.dtype().size();
        let data = t.bytes();

        // Chunk boundaries aligned to element size.
        let chunk = self.chunk_bytes - (self.chunk_bytes % elem.max(1));
        let chunk = chunk.max(elem);
        let chunks: Vec<&[u8]> = if data.is_empty() {
            vec![]
        } else {
            data.chunks(chunk).collect()
        };

        let level = self.level;
        let compressed: Vec<Vec<u8>> = par::try_par_map(
            &chunks,
            chunk_threads(data.len()),
            |_, raw| -> Result<Vec<u8>> {
                if use_shuffle {
                    // Shuffle into the worker's reusable scratch, then
                    // compress from it — no per-chunk shuffle `Vec`.
                    CHUNK_SCRATCH.with(|s| {
                        let mut s = s.borrow_mut();
                        byte_shuffle_into(raw, elem, &mut s);
                        zstd::bulk::compress(&s, level).context("zstd compress")
                    })
                } else {
                    zstd::bulk::compress(raw, level).context("zstd compress")
                }
            },
        )?;

        let header = Mp::map_from(vec![
            ("dtype", Mp::Str(t.dtype().name().to_string())),
            (
                "shape",
                Mp::Arr(t.shape().iter().map(|&d| Mp::UInt(d as u64)).collect()),
            ),
            ("chunk", Mp::UInt(chunk as u64)),
            ("shuffle", Mp::Bool(use_shuffle)),
            (
                "chunks",
                Mp::Arr(
                    compressed
                        .iter()
                        .map(|c| Mp::UInt(c.len() as u64))
                        .collect(),
                ),
            ),
        ])
        .encode();

        let mut out = Vec::with_capacity(
            TS_MAGIC.len() + 4 + header.len() + compressed.iter().map(|c| c.len()).sum::<usize>(),
        );
        out.extend_from_slice(TS_MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(&header);
        for c in &compressed {
            out.extend_from_slice(c);
        }
        Ok(out)
    }

    fn deserialize(&self, bytes: &[u8]) -> Result<Tensor> {
        if bytes.len() < 8 || &bytes[..4] != TS_MAGIC {
            bail!("tensorstore: bad magic");
        }
        let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        if bytes.len() < 8 + hlen {
            bail!("tensorstore: truncated header");
        }
        let header = Mp::decode(&bytes[8..8 + hlen]).context("tensorstore header")?;
        let dtype = DType::parse(
            header
                .get("dtype")
                .and_then(|v| v.as_str())
                .context("missing dtype")?,
        )
        .context("bad dtype")?;
        let shape: Vec<usize> = header
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("missing shape")?
            .iter()
            .map(|d| d.as_u64().map(|v| v as usize).context("bad dim"))
            .collect::<Result<_>>()?;
        let shuffle = header
            .get("shuffle")
            .and_then(|v| match v {
                Mp::Bool(b) => Some(*b),
                _ => None,
            })
            .unwrap_or(false);
        let chunk = header
            .get("chunk")
            .and_then(|v| v.as_u64())
            .context("missing chunk size")? as usize;
        let chunk_lens: Vec<usize> = header
            .get("chunks")
            .and_then(|v| v.as_arr())
            .context("missing chunks")?
            .iter()
            .map(|c| c.as_u64().map(|v| v as usize).context("bad chunk len"))
            .collect::<Result<_>>()?;

        let total: usize = shape.iter().product::<usize>() * dtype.size();
        let elem = dtype.size();

        // Chunk layout invariants: every chunk except the last holds
        // exactly `chunk` raw bytes, so chunk i's output offset is
        // i * chunk. Validate up front so a corrupt header fails
        // cleanly instead of scattering out of bounds.
        if total > 0 {
            if chunk == 0 {
                bail!("tensorstore: zero chunk size");
            }
            let expected = (total + chunk - 1) / chunk;
            if chunk_lens.len() != expected {
                bail!(
                    "tensorstore: {} chunks but layout needs {expected}",
                    chunk_lens.len()
                );
            }
        } else if !chunk_lens.is_empty() {
            bail!("tensorstore: empty tensor with chunk data");
        }

        // Slice out the compressed chunks.
        let mut spans = Vec::with_capacity(chunk_lens.len());
        let mut pos = 8 + hlen;
        for len in &chunk_lens {
            if pos + len > bytes.len() {
                bail!("tensorstore: truncated chunk data");
            }
            spans.push(&bytes[pos..pos + len]);
            pos += len;
        }

        let data = if legacy_decode() {
            decode_copying(&spans, total, elem, shuffle)?
        } else {
            decode_in_place(&spans, total, chunk, elem, shuffle)?
        };
        Tensor::from_bytes(dtype, shape, data).context("tensorstore payload")
    }
}

/// In-place decode: one whole-tensor buffer, each chunk decompressed
/// directly into its `i * chunk` slice, unshuffle fused into the
/// scatter write.
fn decode_in_place(
    spans: &[&[u8]],
    total: usize,
    chunk: usize,
    elem: usize,
    shuffle: bool,
) -> Result<Vec<u8>> {
    let mut data = vec![0u8; total];
    if total == 0 {
        return Ok(data);
    }
    let work: Vec<(&[u8], &mut [u8])> = spans
        .iter()
        .copied()
        .zip(data.chunks_mut(chunk))
        .collect();
    par::try_par_consume(
        work,
        chunk_threads(total),
        |_, (span, dst)| -> Result<()> {
            let expect = dst.len();
            let written = if shuffle {
                CHUNK_SCRATCH.with(|s| -> Result<usize> {
                    let mut s = s.borrow_mut();
                    s.clear();
                    s.resize(expect, 0);
                    let n = zstd::bulk::decompress_to_buffer(span, &mut s[..])
                        .context("zstd decompress")?;
                    if n == expect {
                        byte_unshuffle_into(&s, elem, dst);
                    }
                    Ok(n)
                })?
            } else {
                zstd::bulk::decompress_to_buffer(span, &mut *dst).context("zstd decompress")?
            };
            if written != expect {
                bail!("tensorstore: chunk decompressed to {written} bytes, expected {expect}");
            }
            Ok(())
        },
    )?;
    Ok(data)
}

/// The pre-engine copying decode: a `Vec` per chunk (allocated at
/// whole-tensor capacity, the over-allocation this engine removed) and
/// a final assembly copy. Kept only as the `bench checkout` baseline.
fn decode_copying(spans: &[&[u8]], total: usize, elem: usize, shuffle: bool) -> Result<Vec<u8>> {
    let decompressed: Vec<Vec<u8>> = par::try_par_map(
        spans,
        chunk_threads(total),
        |_, span| -> Result<Vec<u8>> {
            let raw = zstd::bulk::decompress(span, total.max(1)).context("zstd decompress")?;
            Ok(if shuffle {
                byte_unshuffle(&raw, elem)
            } else {
                raw
            })
        },
    )?;
    let mut data = Vec::with_capacity(total);
    for d in decompressed {
        data.extend_from_slice(&d);
    }
    if data.len() != total {
        bail!(
            "tensorstore: chunks decompressed to {} bytes, expected {total}",
            data.len()
        );
    }
    Ok(data)
}

/// Transpose bytes: [e0b0 e0b1 ... | e1b0 e1b1 ...] → all b0s, all b1s, ...
pub fn byte_shuffle(data: &[u8], elem: usize) -> Vec<u8> {
    let mut out = Vec::new();
    byte_shuffle_into(data, elem, &mut out);
    out
}

/// [`byte_shuffle`] into a caller-provided buffer (cleared and resized
/// to `data.len()`), so hot loops can reuse one scratch allocation.
/// Lengths that are not a multiple of `elem` pass through unchanged,
/// matching [`byte_shuffle`].
pub fn byte_shuffle_into(data: &[u8], elem: usize, out: &mut Vec<u8>) {
    out.clear();
    if elem <= 1 || data.len() % elem != 0 {
        out.extend_from_slice(data);
        return;
    }
    out.resize(data.len(), 0);
    let n = data.len() / elem;
    for b in 0..elem {
        let dst = &mut out[b * n..(b + 1) * n];
        for (i, d) in dst.iter_mut().enumerate() {
            *d = data[i * elem + b];
        }
    }
}

/// Inverse of [`byte_shuffle`].
pub fn byte_unshuffle(data: &[u8], elem: usize) -> Vec<u8> {
    let mut out = vec![0u8; data.len()];
    byte_unshuffle_into(data, elem, &mut out);
    out
}

/// Inverse of [`byte_shuffle`], scattering directly into `out` (which
/// must be exactly `data.len()` bytes) — the fusion that lets the
/// in-place decoder unshuffle a chunk straight into the final tensor
/// buffer with no intermediate copy.
///
/// Lengths that are not a multiple of `elem` are copied through
/// unchanged, mirroring the shuffle side's pass-through.
pub fn byte_unshuffle_into(data: &[u8], elem: usize, out: &mut [u8]) {
    debug_assert_eq!(data.len(), out.len());
    if elem <= 1 || data.len() % elem != 0 {
        out.copy_from_slice(data);
        return;
    }
    let n = data.len() / elem;
    for b in 0..elem {
        let src = &data[b * n..(b + 1) * n];
        for (i, &s) in src.iter().enumerate() {
            out[i * elem + b] = s;
        }
    }
}

// ----------------------------------------------------------------------
// Registry + combined (multi-tensor) blobs
// ----------------------------------------------------------------------

static REGISTRY: Lazy<RwLock<BTreeMap<String, &'static dyn Serializer>>> = Lazy::new(|| {
    let mut m: BTreeMap<String, &'static dyn Serializer> = BTreeMap::new();
    let ts: &'static TensorStoreSerializer = Box::leak(Box::new(TensorStoreSerializer::default()));
    m.insert(ts.name().to_string(), ts);
    RwLock::new(m)
});

/// Register a user serializer plug-in.
pub fn register_serializer(s: Box<dyn Serializer>) {
    let s: &'static dyn Serializer = Box::leak(s);
    REGISTRY.write().unwrap().insert(s.name().to_string(), s);
}

/// Look up a serializer by name.
pub fn serializer(name: &str) -> Option<&'static dyn Serializer> {
    REGISTRY.read().unwrap().get(name).copied()
}

/// The default serializer ("tensorstore").
pub fn default_serializer() -> &'static dyn Serializer {
    serializer("tensorstore").expect("default serializer registered")
}

/// Serialize a named set of tensors into one msgpack-combined blob
/// (paper: "the serialized values are combined using msgpack").
pub fn serialize_combined(tensors: &BTreeMap<String, Tensor>) -> Result<Vec<u8>> {
    let ser = default_serializer();
    let entries: Vec<(String, Mp)> = tensors
        .iter()
        .map(|(k, t)| Ok((k.clone(), Mp::Bin(ser.serialize(t)?))))
        .collect::<Result<_>>()?;
    Ok(Mp::Map(entries).encode())
}

/// Inverse of [`serialize_combined`].
pub fn deserialize_combined(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let ser = default_serializer();
    let root = Mp::decode(bytes).context("combined blob")?;
    let entries = match root {
        Mp::Map(e) => e,
        _ => bail!("combined blob must be a map"),
    };
    let mut out = BTreeMap::new();
    for (k, v) in entries {
        let bin = v.as_bin().context("combined entry must be bin")?;
        out.insert(k, ser.deserialize(bin)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_tensor(seed: u64, n: usize) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let vals: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
        Tensor::from_f32(vec![n], vals).unwrap()
    }

    #[test]
    fn shuffle_roundtrip() {
        let data: Vec<u8> = (0..64u8).collect();
        for elem in [1usize, 2, 4, 8] {
            assert_eq!(byte_unshuffle(&byte_shuffle(&data, elem), elem), data);
        }
        // Non-multiple lengths pass through unchanged.
        assert_eq!(byte_shuffle(&data[..63], 4), &data[..63]);
        assert_eq!(byte_unshuffle(&data[..63], 4), &data[..63]);
        // The into-variants agree with the allocating ones.
        let mut buf = Vec::new();
        byte_shuffle_into(&data, 4, &mut buf);
        assert_eq!(buf, byte_shuffle(&data, 4));
        let mut out = vec![0u8; buf.len()];
        byte_unshuffle_into(&buf, 4, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn serialize_roundtrip_f32() {
        let ser = TensorStoreSerializer::default();
        let t = random_tensor(1, 10_000);
        let bytes = ser.serialize(&t).unwrap();
        assert_eq!(ser.deserialize(&bytes).unwrap(), t);
    }

    #[test]
    fn serialize_roundtrip_multi_chunk() {
        let ser = TensorStoreSerializer {
            chunk_bytes: 1024,
            ..Default::default()
        };
        let t = random_tensor(2, 5_000); // 20 KB -> 20 chunks
        let bytes = ser.serialize(&t).unwrap();
        assert_eq!(ser.deserialize(&bytes).unwrap(), t);
    }

    #[test]
    fn in_place_and_legacy_decode_agree() {
        let ser = TensorStoreSerializer {
            chunk_bytes: 512,
            ..Default::default()
        };
        // Shuffled float and unshuffled int payloads, plus a tail
        // chunk shorter than the chunk size.
        for t in [
            random_tensor(11, 3_333),
            Tensor::from_i64(vec![777], (0..777).map(|i| i * 7 - 99).collect()).unwrap(),
        ] {
            let bytes = ser.serialize(&t).unwrap();
            let fast = ser.deserialize(&bytes).unwrap();
            set_legacy_decode(true);
            let slow = ser.deserialize(&bytes);
            set_legacy_decode(false);
            assert_eq!(fast, slow.unwrap());
            assert_eq!(fast, t);
        }
    }

    #[test]
    fn serialize_roundtrip_int_and_empty() {
        let ser = TensorStoreSerializer::default();
        let t = Tensor::from_i64(vec![3], vec![1, -5, 1 << 40]).unwrap();
        assert_eq!(ser.deserialize(&ser.serialize(&t).unwrap()).unwrap(), t);
        let empty = Tensor::from_f32(vec![0], vec![]).unwrap();
        assert_eq!(
            ser.deserialize(&ser.serialize(&empty).unwrap()).unwrap(),
            empty
        );
    }

    #[test]
    fn bf16_valued_f32_compresses_about_2x() {
        // Reproduce the Table 1 effect: f32 checkpoint holding
        // bf16-precision values (low mantissa bytes all zero).
        let mut rng = Pcg64::new(3);
        let n = 100_000;
        let vals: Vec<f32> = (0..n)
            .map(|_| {
                let v = (rng.next_f32() - 0.5) * 2.0;
                crate::tensor::bf16_to_f32(crate::tensor::f32_to_bf16(v))
            })
            .collect();
        let t = Tensor::from_f32(vec![n], vals).unwrap();
        let ser = TensorStoreSerializer::default();
        let bytes = ser.serialize(&t).unwrap();
        let ratio = t.nbytes() as f64 / bytes.len() as f64;
        assert!(ratio > 1.7, "compression ratio only {ratio:.2}");
        assert_eq!(ser.deserialize(&bytes).unwrap(), t);
    }

    #[test]
    fn shuffle_beats_no_shuffle_on_bf16_data() {
        let mut rng = Pcg64::new(4);
        let vals: Vec<f32> = (0..50_000)
            .map(|_| {
                let v = (rng.next_f32() - 0.5) * 2.0;
                crate::tensor::bf16_to_f32(crate::tensor::f32_to_bf16(v))
            })
            .collect();
        let t = Tensor::from_f32(vec![vals.len()], vals).unwrap();
        let with = TensorStoreSerializer::default().serialize(&t).unwrap();
        let without = TensorStoreSerializer {
            shuffle: false,
            ..Default::default()
        }
        .serialize(&t)
        .unwrap();
        assert!(with.len() < without.len());
    }

    #[test]
    fn combined_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("values".to_string(), random_tensor(5, 100));
        m.insert(
            "indices".to_string(),
            Tensor::from_i64(vec![4], vec![0, 5, 9, 99]).unwrap(),
        );
        let blob = serialize_combined(&m).unwrap();
        assert_eq!(deserialize_combined(&blob).unwrap(), m);
    }

    #[test]
    fn registry_lookup() {
        assert!(serializer("tensorstore").is_some());
        assert!(serializer("missing").is_none());
    }

    #[test]
    fn rejects_corrupt() {
        let ser = TensorStoreSerializer::default();
        assert!(ser.deserialize(b"nope").is_err());
        let t = random_tensor(6, 100);
        let mut bytes = ser.serialize(&t).unwrap();
        bytes.truncate(bytes.len() - 10);
        assert!(ser.deserialize(&bytes).is_err());
    }

    #[test]
    fn rejects_corrupt_multi_chunk() {
        let ser = TensorStoreSerializer {
            chunk_bytes: 256,
            ..Default::default()
        };
        let t = random_tensor(8, 1_000);
        let good = ser.serialize(&t).unwrap();
        // Truncating inside the chunk stream fails in both decoders.
        for legacy in [false, true] {
            set_legacy_decode(legacy);
            let r = ser.deserialize(&good[..good.len() - 100]);
            set_legacy_decode(false);
            assert!(r.is_err(), "legacy={legacy}");
        }
    }
}
