//! Serializer plug-ins (paper §3.3 "Serialization").
//!
//! The paper serializes parameter-group tensors with TensorStore, whose
//! chunked, compressed layout is what makes even full dense commits
//! smaller than raw checkpoints (Table 1: T0-3B is distributed as an
//! f32 checkpoint holding bf16-trained values, which compresses ~2×).
//! [`TensorStoreSerializer`] reproduces that architecture: fixed-size
//! chunks, an optional byte-shuffle filter that groups the i-th byte of
//! every element together (turning the all-zero low-mantissa bytes of
//! bf16-valued f32 data into long runs), and zstd per chunk, compressed
//! in parallel.
//!
//! Multi-tensor updates (e.g. sparse = indices + values) are combined
//! into one blob with msgpack, as in the paper.

use crate::tensor::{DType, Tensor};
use crate::util::msgpack::Mp;
use crate::util::par;
use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;
use std::collections::BTreeMap;
use std::sync::RwLock;

/// A tensor serializer plug-in.
pub trait Serializer: Send + Sync {
    fn name(&self) -> &'static str;
    fn serialize(&self, t: &Tensor) -> Result<Vec<u8>>;
    fn deserialize(&self, bytes: &[u8]) -> Result<Tensor>;
}

/// Chunked + byte-shuffled + zstd-compressed serializer.
pub struct TensorStoreSerializer {
    /// Chunk size in bytes (pre-compression).
    pub chunk_bytes: usize,
    /// zstd level (1..=19).
    pub level: i32,
    /// Apply the byte-shuffle filter to float dtypes.
    pub shuffle: bool,
}

impl Default for TensorStoreSerializer {
    fn default() -> Self {
        TensorStoreSerializer {
            chunk_bytes: 4 << 20,
            level: 3,
            shuffle: true,
        }
    }
}

const TS_MAGIC: &[u8; 4] = b"TST1";

impl Serializer for TensorStoreSerializer {
    fn name(&self) -> &'static str {
        "tensorstore"
    }

    fn serialize(&self, t: &Tensor) -> Result<Vec<u8>> {
        let use_shuffle = self.shuffle && t.dtype().is_float();
        let elem = t.dtype().size();
        let data = t.bytes();

        // Chunk boundaries aligned to element size.
        let chunk = self.chunk_bytes - (self.chunk_bytes % elem.max(1));
        let chunk = chunk.max(elem);
        let chunks: Vec<&[u8]> = if data.is_empty() {
            vec![]
        } else {
            data.chunks(chunk).collect()
        };

        // Shuffle+compress chunks in parallel — but only for tensors big
        // enough to matter; the clean filter already parallelizes across
        // parameter groups, and nested thread pools hurt (§Perf).
        let level = self.level;
        let par_threads = if data.len() >= 16 << 20 { par::default_threads() } else { 1 };
        let compressed: Vec<Vec<u8>> = par::try_par_map(
            &chunks,
            par_threads,
            |_, raw| -> Result<Vec<u8>> {
                let shuffled;
                let input: &[u8] = if use_shuffle {
                    shuffled = byte_shuffle(raw, elem);
                    &shuffled
                } else {
                    raw
                };
                zstd::bulk::compress(input, level).context("zstd compress")
            },
        )?;

        let header = Mp::map_from(vec![
            ("dtype", Mp::Str(t.dtype().name().to_string())),
            (
                "shape",
                Mp::Arr(t.shape().iter().map(|&d| Mp::UInt(d as u64)).collect()),
            ),
            ("chunk", Mp::UInt(chunk as u64)),
            ("shuffle", Mp::Bool(use_shuffle)),
            (
                "chunks",
                Mp::Arr(
                    compressed
                        .iter()
                        .map(|c| Mp::UInt(c.len() as u64))
                        .collect(),
                ),
            ),
        ])
        .encode();

        let mut out = Vec::with_capacity(
            TS_MAGIC.len() + 4 + header.len() + compressed.iter().map(|c| c.len()).sum::<usize>(),
        );
        out.extend_from_slice(TS_MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(&header);
        for c in &compressed {
            out.extend_from_slice(c);
        }
        Ok(out)
    }

    fn deserialize(&self, bytes: &[u8]) -> Result<Tensor> {
        if bytes.len() < 8 || &bytes[..4] != TS_MAGIC {
            bail!("tensorstore: bad magic");
        }
        let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        if bytes.len() < 8 + hlen {
            bail!("tensorstore: truncated header");
        }
        let header = Mp::decode(&bytes[8..8 + hlen]).context("tensorstore header")?;
        let dtype = DType::parse(
            header
                .get("dtype")
                .and_then(|v| v.as_str())
                .context("missing dtype")?,
        )
        .context("bad dtype")?;
        let shape: Vec<usize> = header
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("missing shape")?
            .iter()
            .map(|d| d.as_u64().map(|v| v as usize).context("bad dim"))
            .collect::<Result<_>>()?;
        let shuffle = header
            .get("shuffle")
            .and_then(|v| match v {
                Mp::Bool(b) => Some(*b),
                _ => None,
            })
            .unwrap_or(false);
        let chunk_lens: Vec<usize> = header
            .get("chunks")
            .and_then(|v| v.as_arr())
            .context("missing chunks")?
            .iter()
            .map(|c| c.as_u64().map(|v| v as usize).context("bad chunk len"))
            .collect::<Result<_>>()?;

        let total: usize = shape.iter().product::<usize>() * dtype.size();
        let elem = dtype.size();

        // Slice out the compressed chunks.
        let mut spans = Vec::with_capacity(chunk_lens.len());
        let mut pos = 8 + hlen;
        for len in &chunk_lens {
            if pos + len > bytes.len() {
                bail!("tensorstore: truncated chunk data");
            }
            spans.push(&bytes[pos..pos + len]);
            pos += len;
        }

        let par_threads = if total >= 16 << 20 { par::default_threads() } else { 1 };
        let decompressed: Vec<Vec<u8>> = par::try_par_map(
            &spans,
            par_threads,
            |_, span| -> Result<Vec<u8>> {
                let raw = zstd::bulk::decompress(span, total.max(1)).context("zstd decompress")?;
                Ok(if shuffle {
                    byte_unshuffle(&raw, elem)
                } else {
                    raw
                })
            },
        )?;

        let mut data = Vec::with_capacity(total);
        for d in decompressed {
            data.extend_from_slice(&d);
        }
        Tensor::from_bytes(dtype, shape, data).context("tensorstore payload")
    }
}

/// Transpose bytes: [e0b0 e0b1 ... | e1b0 e1b1 ...] → all b0s, all b1s, ...
pub fn byte_shuffle(data: &[u8], elem: usize) -> Vec<u8> {
    if elem <= 1 || data.len() % elem != 0 {
        return data.to_vec();
    }
    let n = data.len() / elem;
    let mut out = vec![0u8; data.len()];
    for b in 0..elem {
        let dst = &mut out[b * n..(b + 1) * n];
        for (i, d) in dst.iter_mut().enumerate() {
            *d = data[i * elem + b];
        }
    }
    out
}

/// Inverse of [`byte_shuffle`].
pub fn byte_unshuffle(data: &[u8], elem: usize) -> Vec<u8> {
    if elem <= 1 || data.len() % elem != 0 {
        return data.to_vec();
    }
    let n = data.len() / elem;
    let mut out = vec![0u8; data.len()];
    for b in 0..elem {
        let src = &data[b * n..(b + 1) * n];
        for (i, &s) in src.iter().enumerate() {
            out[i * elem + b] = s;
        }
    }
    out
}

// ----------------------------------------------------------------------
// Registry + combined (multi-tensor) blobs
// ----------------------------------------------------------------------

static REGISTRY: Lazy<RwLock<BTreeMap<String, &'static dyn Serializer>>> = Lazy::new(|| {
    let mut m: BTreeMap<String, &'static dyn Serializer> = BTreeMap::new();
    let ts: &'static TensorStoreSerializer = Box::leak(Box::new(TensorStoreSerializer::default()));
    m.insert(ts.name().to_string(), ts);
    RwLock::new(m)
});

/// Register a user serializer plug-in.
pub fn register_serializer(s: Box<dyn Serializer>) {
    let s: &'static dyn Serializer = Box::leak(s);
    REGISTRY.write().unwrap().insert(s.name().to_string(), s);
}

/// Look up a serializer by name.
pub fn serializer(name: &str) -> Option<&'static dyn Serializer> {
    REGISTRY.read().unwrap().get(name).copied()
}

/// The default serializer ("tensorstore").
pub fn default_serializer() -> &'static dyn Serializer {
    serializer("tensorstore").expect("default serializer registered")
}

/// Serialize a named set of tensors into one msgpack-combined blob
/// (paper: "the serialized values are combined using msgpack").
pub fn serialize_combined(tensors: &BTreeMap<String, Tensor>) -> Result<Vec<u8>> {
    let ser = default_serializer();
    let entries: Vec<(String, Mp)> = tensors
        .iter()
        .map(|(k, t)| Ok((k.clone(), Mp::Bin(ser.serialize(t)?))))
        .collect::<Result<_>>()?;
    Ok(Mp::Map(entries).encode())
}

/// Inverse of [`serialize_combined`].
pub fn deserialize_combined(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let ser = default_serializer();
    let root = Mp::decode(bytes).context("combined blob")?;
    let entries = match root {
        Mp::Map(e) => e,
        _ => bail!("combined blob must be a map"),
    };
    let mut out = BTreeMap::new();
    for (k, v) in entries {
        let bin = v.as_bin().context("combined entry must be bin")?;
        out.insert(k, ser.deserialize(bin)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_tensor(seed: u64, n: usize) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let vals: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
        Tensor::from_f32(vec![n], vals).unwrap()
    }

    #[test]
    fn shuffle_roundtrip() {
        let data: Vec<u8> = (0..64u8).collect();
        for elem in [1usize, 2, 4, 8] {
            assert_eq!(byte_unshuffle(&byte_shuffle(&data, elem), elem), data);
        }
        // Non-multiple lengths pass through unchanged.
        assert_eq!(byte_shuffle(&data[..63], 4), &data[..63]);
    }

    #[test]
    fn serialize_roundtrip_f32() {
        let ser = TensorStoreSerializer::default();
        let t = random_tensor(1, 10_000);
        let bytes = ser.serialize(&t).unwrap();
        assert_eq!(ser.deserialize(&bytes).unwrap(), t);
    }

    #[test]
    fn serialize_roundtrip_multi_chunk() {
        let ser = TensorStoreSerializer {
            chunk_bytes: 1024,
            ..Default::default()
        };
        let t = random_tensor(2, 5_000); // 20 KB -> 20 chunks
        let bytes = ser.serialize(&t).unwrap();
        assert_eq!(ser.deserialize(&bytes).unwrap(), t);
    }

    #[test]
    fn serialize_roundtrip_int_and_empty() {
        let ser = TensorStoreSerializer::default();
        let t = Tensor::from_i64(vec![3], vec![1, -5, 1 << 40]).unwrap();
        assert_eq!(ser.deserialize(&ser.serialize(&t).unwrap()).unwrap(), t);
        let empty = Tensor::from_f32(vec![0], vec![]).unwrap();
        assert_eq!(
            ser.deserialize(&ser.serialize(&empty).unwrap()).unwrap(),
            empty
        );
    }

    #[test]
    fn bf16_valued_f32_compresses_about_2x() {
        // Reproduce the Table 1 effect: f32 checkpoint holding
        // bf16-precision values (low mantissa bytes all zero).
        let mut rng = Pcg64::new(3);
        let n = 100_000;
        let vals: Vec<f32> = (0..n)
            .map(|_| {
                let v = (rng.next_f32() - 0.5) * 2.0;
                crate::tensor::bf16_to_f32(crate::tensor::f32_to_bf16(v))
            })
            .collect();
        let t = Tensor::from_f32(vec![n], vals).unwrap();
        let ser = TensorStoreSerializer::default();
        let bytes = ser.serialize(&t).unwrap();
        let ratio = t.nbytes() as f64 / bytes.len() as f64;
        assert!(ratio > 1.7, "compression ratio only {ratio:.2}");
        assert_eq!(ser.deserialize(&bytes).unwrap(), t);
    }

    #[test]
    fn shuffle_beats_no_shuffle_on_bf16_data() {
        let mut rng = Pcg64::new(4);
        let vals: Vec<f32> = (0..50_000)
            .map(|_| {
                let v = (rng.next_f32() - 0.5) * 2.0;
                crate::tensor::bf16_to_f32(crate::tensor::f32_to_bf16(v))
            })
            .collect();
        let t = Tensor::from_f32(vec![vals.len()], vals).unwrap();
        let with = TensorStoreSerializer::default().serialize(&t).unwrap();
        let without = TensorStoreSerializer {
            shuffle: false,
            ..Default::default()
        }
        .serialize(&t)
        .unwrap();
        assert!(with.len() < without.len());
    }

    #[test]
    fn combined_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("values".to_string(), random_tensor(5, 100));
        m.insert(
            "indices".to_string(),
            Tensor::from_i64(vec![4], vec![0, 5, 9, 99]).unwrap(),
        );
        let blob = serialize_combined(&m).unwrap();
        assert_eq!(deserialize_combined(&blob).unwrap(), m);
    }

    #[test]
    fn registry_lookup() {
        assert!(serializer("tensorstore").is_some());
        assert!(serializer("missing").is_none());
    }

    #[test]
    fn rejects_corrupt() {
        let ser = TensorStoreSerializer::default();
        assert!(ser.deserialize(b"nope").is_err());
        let t = random_tensor(6, 100);
        let mut bytes = ser.serialize(&t).unwrap();
        bytes.truncate(bytes.len() - 10);
        assert!(ser.deserialize(&bytes).is_err());
    }
}
