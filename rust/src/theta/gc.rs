//! `git-theta gc` — drop LFS objects no reachable revision references.
//!
//! Snapshot re-anchoring, abandoned staging runs, and merge-strategy
//! resolutions that were never committed all write content-addressed
//! objects into `.theta/lfs/objects` that nothing reachable points at
//! anymore. This module computes the live set — every object
//! referenced by any commit reachable from any branch or HEAD, plus
//! everything the index currently stages — and reports (dry-run) or
//! deletes (`--prune`) the rest.
//!
//! Safety model: liveness is computed from the same metadata walk the
//! transfer hooks use ([`referenced_lfs_oids`]), so an object is only
//! ever considered garbage when no reachable metadata chain or LFS
//! pointer names it. Deletion is opt-in; the default invocation only
//! reports.

use crate::gitcore::index::Index;
use crate::gitcore::mergebase::ancestors;
use crate::gitcore::object::Oid;
use crate::gitcore::repo::Repository;
use crate::lfs::{LfsStore, Pointer};
use crate::theta::hooks::referenced_lfs_oids;
use crate::theta::metadata::ModelMetadata;
use anyhow::Result;
use std::collections::HashSet;

/// What a gc pass found (and, with prune, removed).
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    /// Objects in the local store before the pass.
    pub total: usize,
    /// Objects referenced by a reachable commit or the index.
    pub live: usize,
    /// Unreferenced oids, sorted (deleted when pruning).
    pub orphaned: Vec<Oid>,
    /// Bytes held by the orphaned objects.
    pub orphaned_bytes: u64,
    /// Whether the orphans were actually deleted.
    pub pruned: bool,
    /// Planned orphans the prune *spared* because a concurrent put
    /// re-stored them after the plan was computed (mtime at or after
    /// the gc start — see [`prune_plan`]).
    pub spared: usize,
}

/// Every LFS oid referenced by any commit reachable from any branch or
/// HEAD, plus everything the index currently stages (a staged-but-
/// uncommitted model must survive a gc).
pub fn live_oids(repo: &Repository) -> Result<HashSet<Oid>> {
    let mut tips: Vec<Oid> = repo
        .refs()
        .branches()?
        .into_iter()
        .map(|(_, oid)| oid)
        .collect();
    if let Some(head) = repo.head_commit()? {
        tips.push(head); // covers a detached HEAD
    }
    let mut commits: HashSet<Oid> = HashSet::new();
    for tip in tips {
        commits.extend(ancestors(repo.odb(), tip)?);
    }

    let mut live: HashSet<Oid> = HashSet::new();
    let mut seen_trees: HashSet<Oid> = HashSet::new();
    for commit in &commits {
        let c = repo.odb().read_commit(commit)?;
        // Many commits share trees (e.g. merges, reverts); walk each
        // tree's blobs once.
        if !seen_trees.insert(c.tree) {
            continue;
        }
        let tree = repo.odb().read_tree(&c.tree)?;
        live.extend(referenced_lfs_oids(repo, &tree)?);
    }

    let index = Index::load(repo.theta_dir())?;
    for (_, entry) in index.iter() {
        let blob = repo.odb().read_blob(&entry.oid)?;
        if ModelMetadata::is_metadata(&blob) {
            if let Ok(meta) = ModelMetadata::from_bytes(&blob) {
                live.extend(meta.all_oids());
            }
        } else {
            live.extend(Pointer::oid_of_blob(&blob));
        }
    }
    Ok(live)
}

/// Compute a gc plan without deleting anything: the report plus the
/// instant liveness was computed. The timestamp is the prune's safety
/// anchor — any planned orphan whose store mtime moves to or past it
/// was re-stored by a concurrent put ([`LfsStore::put`] freshens
/// mtimes on dedup hits) and must not be deleted.
pub fn plan_garbage(repo: &Repository) -> Result<(GcReport, std::time::SystemTime)> {
    let started = std::time::SystemTime::now();
    let store = LfsStore::open(repo.theta_dir());
    let live = live_oids(repo)?;
    let mut stored = store.list()?;
    stored.sort();

    let mut report = GcReport {
        total: stored.len(),
        ..Default::default()
    };
    for oid in stored {
        if live.contains(&oid) {
            report.live += 1;
        } else {
            report.orphaned_bytes += store.size_of(&oid).unwrap_or(0);
            report.orphaned.push(oid);
        }
    }
    Ok((report, started))
}

/// Delete a plan's orphans, **sparing** any the store has touched since
/// `started`: a put racing this prune re-stores content the plan
/// already classified as garbage, and its mtime freshen (see
/// [`LfsStore::put`]) is the signal that the object is live again.
/// Spared oids move out of `orphaned` and are counted in `spared`.
pub fn prune_plan(
    store: &LfsStore,
    report: &mut GcReport,
    started: std::time::SystemTime,
) -> Result<()> {
    let mut kept: Vec<Oid> = Vec::new();
    for oid in &report.orphaned {
        match store.modified_of(oid) {
            Some(mtime) if mtime >= started => kept.push(*oid),
            _ => {
                store.delete(oid)?;
            }
        }
    }
    if !kept.is_empty() {
        report.orphaned.retain(|o| !kept.contains(o));
        report.spared = kept.len();
        report.live += kept.len();
    }
    report.pruned = true;
    Ok(())
}

/// Find — and with `prune`, delete — store objects unreachable from
/// every branch, HEAD, and the index. Dry-run by default: callers must
/// opt into deletion.
pub fn collect_garbage(repo: &Repository, prune: bool) -> Result<GcReport> {
    let (mut report, started) = plan_garbage(repo)?;
    if prune {
        let store = LfsStore::open(repo.theta_dir());
        prune_plan(&store, &mut report, started)?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{Checkpoint, CheckpointFormat, SafetensorsFormat};
    use crate::gitcore::attributes::Attributes;
    use crate::tensor::Tensor;
    use crate::util::tmp::TempDir;

    fn setup_repo() -> (TempDir, Repository) {
        crate::init();
        let td = TempDir::new("gc").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        Attributes::add_line(
            repo.worktree(),
            "*.safetensors filter=theta diff=theta merge=theta",
        )
        .unwrap();
        (td, repo)
    }

    fn write_ck(td: &TempDir, w: Vec<f32>) {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![w.len()], w).unwrap());
        SafetensorsFormat
            .save_file(&ck, &td.join("model.safetensors"))
            .unwrap();
    }

    #[test]
    fn gc_reports_then_prunes_orphans_only() {
        let (td, repo) = setup_repo();
        write_ck(&td, vec![1.0; 64]);
        repo.add(&["model.safetensors", ".thetaattributes"]).unwrap();
        repo.commit("v1", "t").unwrap();

        let store = LfsStore::open(repo.theta_dir());
        let live_before = store.list().unwrap().len();
        assert!(live_before >= 1);
        let (junk, _) = store.put(b"abandoned merge resolution").unwrap();

        // Dry run: reports the orphan, deletes nothing.
        let report = collect_garbage(&repo, false).unwrap();
        assert_eq!(report.total, live_before + 1);
        assert_eq!(report.live, live_before);
        assert_eq!(report.orphaned, vec![junk]);
        assert!(report.orphaned_bytes > 0);
        assert!(!report.pruned);
        assert!(store.contains(&junk));

        // Prune: the orphan goes, live objects stay, checkout works.
        let report = collect_garbage(&repo, true).unwrap();
        assert!(report.pruned);
        assert!(!store.contains(&junk));
        assert_eq!(store.list().unwrap().len(), live_before);
        repo.checkout("main").unwrap();

        // A second pass finds nothing.
        let report = collect_garbage(&repo, true).unwrap();
        assert!(report.orphaned.is_empty());
    }

    #[test]
    fn put_between_plan_and_prune_is_spared() {
        let (td, repo) = setup_repo();
        write_ck(&td, vec![3.0; 48]);
        repo.add(&["model.safetensors", ".thetaattributes"]).unwrap();
        repo.commit("v1", "t").unwrap();

        let store = LfsStore::open(repo.theta_dir());
        let payload = b"resolution a merge worker is about to re-store";
        let (orphan, _) = store.put(payload).unwrap();
        // Age it so only the freshen (not the original write) can save it.
        let old = std::time::SystemTime::now() - std::time::Duration::from_secs(3600);
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(td.path().join(".theta/lfs/objects").join({
                let hex = orphan.to_hex();
                format!("{}/{}", &hex[..2], &hex[2..])
            }))
            .unwrap();
        f.set_modified(old).unwrap();
        drop(f);

        let (mut report, started) = plan_garbage(&repo).unwrap();
        assert_eq!(report.orphaned, vec![orphan]);

        // The race: a concurrent worker re-stores the same content
        // after the plan was computed but before the prune deletes it.
        store.put(payload).unwrap();

        prune_plan(&store, &mut report, started).unwrap();
        assert!(
            store.contains(&orphan),
            "prune deleted an object a concurrent put had re-stored"
        );
        assert_eq!(report.spared, 1);
        assert!(report.orphaned.is_empty());
        assert!(report.pruned);
    }

    #[test]
    fn staged_but_uncommitted_objects_are_live() {
        let (td, repo) = setup_repo();
        write_ck(&td, vec![2.0; 32]);
        repo.add(&["model.safetensors", ".thetaattributes"]).unwrap();
        // No commit: the only reference is the index.
        let store = LfsStore::open(repo.theta_dir());
        assert!(!store.list().unwrap().is_empty());
        let report = collect_garbage(&repo, true).unwrap();
        assert!(report.orphaned.is_empty(), "{report:?}");
        assert_eq!(report.live, report.total);
    }

    #[test]
    fn all_branches_keep_their_objects() {
        let (td, repo) = setup_repo();
        write_ck(&td, vec![1.0; 32]);
        repo.add(&["model.safetensors", ".thetaattributes"]).unwrap();
        repo.commit("base", "t").unwrap();
        repo.create_branch("side").unwrap();
        repo.checkout("side").unwrap();
        write_ck(&td, vec![5.0; 32]);
        repo.add(&["model.safetensors"]).unwrap();
        repo.commit("side edit", "t").unwrap();
        repo.checkout("main").unwrap();

        // Objects referenced only by `side` must stay live from main.
        let report = collect_garbage(&repo, true).unwrap();
        assert!(report.orphaned.is_empty(), "{report:?}");
        repo.checkout("side").unwrap();
        repo.checkout("main").unwrap();
    }
}
