//! Git-Theta core: the paper's system contribution.
//!
//! * [`lsh`] — locality-sensitive hashing for change detection.
//! * [`updates`] — dense/sparse/low-rank/IA3/trim update plug-ins.
//! * [`serialize`] — TensorStore-style chunked+compressed serializer
//!   with in-place parallel chunk decode.
//! * [`metadata`] — the model metadata file Git versions.
//! * [`filter`] — the clean/smudge filters.
//! * [`checkout`] — the checkout compute engine: chain snapshotting
//!   and memoized reconstruction.
//! * [`diff`] — the parameter-group diff driver (metadata-level plus
//!   the `--exact` value-level mode).
//! * [`merge`] — the merge driver, strategy plug-ins, and the
//!   group-parallel merge engine.
//! * [`gc`] — `git-theta gc`: drops LFS objects no reachable revision
//!   references.
//! * [`hooks`] — post-commit / pre-push LFS object bookkeeping.
//! * [`track`] — `git theta track`.

// rustdoc burn-down (see lib.rs): every `theta` module is now fully
// documented and participates in `missing_docs`.
pub mod checkout;
pub mod diff;
pub mod filter;
pub mod gc;
pub mod hooks;
pub mod lsh;
pub mod merge;
pub mod merge_ext;
pub mod metadata;
pub mod serialize;
pub mod track;
pub mod updates;

pub use checkout::{snapshot_metadata, ReconstructionCache, DEFAULT_SNAPSHOT_DEPTH};
pub use diff::{exact_diff, render_diff, set_exact_diff, ModelDiff, ThetaDiff, ValueDelta};
pub use filter::{
    clean_checkpoint, clean_checkpoint_opts, reconstruct_group, smudge_metadata,
    smudge_metadata_opts, CleanOptions, ObjectAccess, ThetaFilter,
};
pub use gc::{collect_garbage, plan_garbage, prune_plan, GcReport};
pub use hooks::ThetaHooks;
pub use merge::{
    merge_metadata, merge_metadata_opts, register_merge_strategy, EngineOptions, MergeStats,
    ThetaMerge,
};
pub use metadata::{GroupMetadata, ModelMetadata, ObjRef};
pub use track::{is_tracked, track};
pub use updates::{infer_best, register_update_type, update_type, UpdatePayload, UpdateType};

use crate::gitcore::drivers::DriverRegistry;
use std::sync::Arc;

/// Register the theta filter, diff driver, merge driver, and hooks.
pub fn register_theta() {
    merge_ext::register_extension_strategies();
    DriverRegistry::register_filter("theta", Arc::new(ThetaFilter));
    DriverRegistry::register_diff("theta", Arc::new(ThetaDiff));
    DriverRegistry::register_merge("theta", Arc::new(ThetaMerge));
    DriverRegistry::register_hooks(Arc::new(ThetaHooks));
}
