//! The Git-Theta clean/smudge filters (paper §3.2).
//!
//! **clean** (`git add`): load the framework-native checkpoint, compare
//! every parameter group against the prior version via LSH, infer the
//! cheapest update for changed groups, serialize + store update objects
//! in the LFS store, and emit the small metadata file that Git itself
//! versions.
//!
//! **smudge** (`git checkout`): reverse — resolve each group's update
//! chain (fetching LFS objects locally or lazily from the configured
//! remote), reconstruct full parameter values, and reassemble the
//! framework-native checkpoint.
//!
//! Both directions process parameter groups in parallel (paper §4:
//! "Git-Theta leverages the embarrassingly parallel nature of parameter
//! processing").

use crate::checkpoint::{detect_format, format_by_name, Checkpoint};
use crate::gitcore::drivers::FilterDriver;
use crate::gitcore::object::Oid;
use crate::gitcore::remote::RemoteSpec;
use crate::gitcore::repo::Repository;
use crate::lfs::{batch, transport, LfsStore, RemoteTransport};
use crate::tensor::{allclose, Tensor};
use crate::theta::checkout::{self, ReconstructionCache, DEFAULT_SNAPSHOT_DEPTH};
use crate::theta::lsh::{LshSignature, LshVerdict};
use crate::theta::metadata::{GroupMetadata, ModelMetadata, ObjRef, TensorInfo, UpdateInfo};
use crate::theta::serialize::serialize_combined;
use crate::theta::updates::{infer_best, update_type, UpdatePayload};
use crate::util::par;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The `filter=theta` driver.
pub struct ThetaFilter;

/// LFS access bundle: local store + optional lazy remote transport.
pub struct ObjectAccess {
    /// The repository-local content-addressed store.
    pub store: LfsStore,
    /// Lazy remote transport (directory or http); `None` means fully
    /// local — a miss is an error instead of a download.
    pub remote: Option<Box<dyn RemoteTransport>>,
}

impl ObjectAccess {
    /// Build the access bundle for a repository: its local store plus
    /// a transport for the configured `remote` (if any), with partial
    /// pack downloads staged under the repo's `.theta` dir so
    /// interrupted fetches resume.
    pub fn for_repo(repo: &Repository) -> Result<ObjectAccess> {
        let remote = match repo.config_get("remote")? {
            Some(spec) => Some(transport::open_transport(
                &RemoteSpec::parse(&spec)?,
                Some(repo.theta_dir()),
            )?),
            None => None,
        };
        Ok(ObjectAccess {
            store: LfsStore::open(repo.theta_dir()),
            remote,
        })
    }

    /// Fetch an object, downloading from the remote on a local miss
    /// (paper: smudge "retrieves the serialized update from either the
    /// local cache in .git/lfs/objects or the LFS remote server").
    ///
    /// This is the lazy single-object path; bulk consumers should call
    /// [`ObjectAccess::prefetch`] first so all misses arrive in one pack.
    pub fn fetch(&self, obj: &ObjRef) -> Result<Vec<u8>> {
        if !self.store.contains(&obj.oid) {
            match &self.remote {
                Some(remote) => {
                    transport::download(remote.as_ref(), &self.store, &[obj.oid])?;
                }
                None => bail!(
                    "lfs object {} not found locally and no remote is configured \
                     (set one with `git-theta config remote <dir|http://host:port>`)",
                    obj.oid.short()
                ),
            }
        }
        self.store.get(&obj.oid)
    }

    /// Ensure `oids` are in the local store, fetching every miss from
    /// the remote in a single negotiation + pack transfer.
    ///
    /// A no-op (zero round trips) when nothing is missing or no remote
    /// is configured; objects the remote also lacks are left for
    /// [`ObjectAccess::fetch`] to report when actually needed.
    pub fn prefetch(&self, oids: &[Oid]) -> Result<()> {
        if let Some(remote) = &self.remote {
            batch::fetch_pack(remote.as_ref(), &self.store, oids)?;
        }
        Ok(())
    }

    /// Ensure every object `meta` references is local, advertising the
    /// metadata's update chains so a chain-aware remote ships only the
    /// missing chain suffixes — as deltas against bases already here.
    /// Same no-op and leftover-miss semantics as
    /// [`ObjectAccess::prefetch`].
    pub fn prefetch_meta(&self, meta: &ModelMetadata) -> Result<()> {
        let Some(remote) = &self.remote else {
            return Ok(());
        };
        let mut seen_tips = std::collections::HashSet::new();
        let mut chains = Vec::new();
        crate::theta::hooks::meta_chain_adverts(meta, &mut seen_tips, &mut chains);
        let adv = transport::ChainAdvert {
            chains,
            want: meta.all_oids(),
        };
        batch::fetch_pack_chains(remote.as_ref(), &self.store, &adv)?;
        Ok(())
    }
}

/// Reconstruct a group's full values from its metadata entry, resolving
/// the incremental chain recursively (paper §3.2 "Checking Out a Model").
///
/// Uncached resolution; bulk callers create a
/// [`ReconstructionCache`] and go through [`checkout::reconstruct`] so
/// shared chain prefixes are computed once per run.
pub fn reconstruct_group(access: &ObjectAccess, entry: &GroupMetadata) -> Result<Tensor> {
    checkout::reconstruct(access, entry, None)
}

/// Tuning knobs for [`clean_checkpoint_opts`].
#[derive(Debug, Clone)]
pub struct CleanOptions {
    /// Pin a specific update type (the paper's per-file override);
    /// `None` lets [`infer_best`] pick the cheapest. A forced type also
    /// disables snapshotting for the affected groups — an explicit
    /// `theta-update` attribute wins over the depth policy.
    pub forced_update: Option<String>,
    /// Re-anchor a changed group densely when its chain would exceed
    /// this depth; `None` disables automatic snapshotting.
    pub snapshot_depth: Option<usize>,
    /// Worker threads for the per-group parallel loop.
    pub threads: usize,
    /// Share a per-run [`ReconstructionCache`] across groups so
    /// `NeedsExactCheck` probes and incremental inference never rebuild
    /// the same chain prefix twice.
    pub cache: bool,
}

impl Default for CleanOptions {
    fn default() -> CleanOptions {
        CleanOptions {
            forced_update: None,
            snapshot_depth: Some(DEFAULT_SNAPSHOT_DEPTH),
            threads: par::default_threads(),
            cache: true,
        }
    }
}

/// Run the clean filter over an in-memory checkpoint. Exposed for the
/// benchmark harness, which needs byte-level control of inputs.
///
/// Shorthand for [`clean_checkpoint_opts`] with default snapshotting
/// and caching.
pub fn clean_checkpoint(
    access: &ObjectAccess,
    ck: &Checkpoint,
    format_name: &str,
    prior: Option<&ModelMetadata>,
    forced_update: Option<&str>,
    threads: usize,
) -> Result<ModelMetadata> {
    let opts = CleanOptions {
        forced_update: forced_update.map(str::to_string),
        threads,
        ..Default::default()
    };
    clean_checkpoint_opts(access, ck, format_name, prior, &opts)
}

/// Run the clean filter with explicit [`CleanOptions`].
pub fn clean_checkpoint_opts(
    access: &ObjectAccess,
    ck: &Checkpoint,
    format_name: &str,
    prior: Option<&ModelMetadata>,
    opts: &CleanOptions,
) -> Result<ModelMetadata> {
    // No up-front prefetch here: unchanged groups (the common case)
    // never reconstruct their prior value, so pulling the prior's whole
    // object closure would over-fetch. Changed groups download lazily;
    // the bulk path that benefits from packing is smudge.
    let cache = if opts.cache {
        Some(ReconstructionCache::new())
    } else {
        None
    };
    let groups: Vec<(&String, &Tensor)> = ck.iter().collect();
    let entries = par::try_par_map(&groups, opts.threads, |_, (name, tensor)| {
        clean_group(access, name, tensor, prior, opts, cache.as_ref())
            .with_context(|| format!("cleaning parameter group '{name}'"))
    })?;
    let mut meta = ModelMetadata::new(format_name);
    for ((name, _), entry) in groups.iter().zip(entries) {
        meta.groups.insert((*name).clone(), entry);
    }
    Ok(meta)
}

fn clean_group(
    access: &ObjectAccess,
    name: &str,
    tensor: &Tensor,
    prior: Option<&ModelMetadata>,
    opts: &CleanOptions,
    cache: Option<&ReconstructionCache>,
) -> Result<GroupMetadata> {
    let sig = LshSignature::of_tensor(tensor)?;
    let prior_entry = prior.and_then(|m| m.groups.get(name));

    if let Some(pe) = prior_entry {
        // Metadata comparison first (paper: "Mismatches in metadata such
        // as parameter shape or dtype immediately signal ... changed").
        if pe.tensor.shape == tensor.shape() && pe.tensor.dtype == tensor.dtype() {
            match sig.compare(&pe.tensor.lsh) {
                LshVerdict::Unchanged => return Ok(pe.clone()),
                LshVerdict::NeedsExactCheck => {
                    // Ambiguous band: exact allclose against the stored
                    // value. The probe's reconstruction memoizes the
                    // chain, so the changed path below reuses it.
                    let prev_value = checkout::reconstruct(access, pe, cache)?;
                    if allclose(tensor, &prev_value, checkout::EXACT_RTOL, checkout::EXACT_ATOL)? {
                        return Ok(pe.clone());
                    }
                    return store_changed(access, tensor, sig, Some((pe, prev_value)), opts);
                }
                LshVerdict::Changed => {}
            }
        }
        // Changed (or shape/dtype mismatch): reconstruct prev for
        // incremental-update inference.
        let prev_value = checkout::reconstruct(access, pe, cache)?;
        return store_changed(access, tensor, sig, Some((pe, prev_value)), opts);
    }

    store_changed(access, tensor, sig, None, opts)
}

fn store_changed(
    access: &ObjectAccess,
    tensor: &Tensor,
    sig: LshSignature,
    prior: Option<(&GroupMetadata, Tensor)>,
    opts: &CleanOptions,
) -> Result<GroupMetadata> {
    let (prior_entry, prev_value) = match &prior {
        Some((pe, pv)) => (Some(*pe), Some(pv)),
        None => (None, None),
    };
    let forced = opts.forced_update.as_deref();
    let mut payload = infer_best(prev_value, tensor, forced)?;

    // Snapshot policy: if this incremental link would push the chain
    // past the configured depth, re-anchor the group densely instead —
    // reconstruction cost at checkout stays bounded, and the full
    // tensor is already in memory so the re-anchor is one dense store.
    // An explicitly forced update type wins over the policy.
    if forced.is_none() {
        if let Some((pe, _)) = &prior {
            let incremental = update_type(&payload.kind)
                .with_context(|| format!("unknown update type '{}'", payload.kind))?
                .requires_prev();
            if incremental && checkout::should_snapshot(pe, opts.snapshot_depth) {
                payload = update_type("dense")
                    .context("dense update type not registered")?
                    .infer(None, tensor)?
                    .context("dense update cannot represent tensor")?;
            }
        }
    }
    store_payload(access, tensor, sig, payload, prior_entry)
}

/// Serialize a payload, store it in LFS, and build the group entry.
pub fn store_payload(
    access: &ObjectAccess,
    tensor: &Tensor,
    sig: LshSignature,
    payload: UpdatePayload,
    prior_entry: Option<&GroupMetadata>,
) -> Result<GroupMetadata> {
    let mut objects = std::collections::BTreeMap::new();
    if !payload.tensors.is_empty() {
        let blob = serialize_combined(&payload.tensors)?;
        let (oid, size) = access.store.put(&blob)?;
        objects.insert("data".to_string(), ObjRef { oid, size });
    }
    let u = update_type(&payload.kind)
        .with_context(|| format!("unknown update type '{}'", payload.kind))?;
    let prev = if u.requires_prev() {
        Some(Box::new(
            prior_entry
                .context("incremental update requires a prior version")?
                .clone(),
        ))
    } else {
        None
    };
    Ok(GroupMetadata {
        tensor: TensorInfo {
            shape: tensor.shape().to_vec(),
            dtype: tensor.dtype(),
            lsh: sig,
        },
        update: UpdateInfo {
            kind: payload.kind,
            objects,
            extra: payload.extra,
        },
        prev,
    })
}

/// Run the smudge filter: metadata → full checkpoint.
///
/// Shorthand for [`smudge_metadata_opts`] with the reconstruction
/// cache *disabled*: a plain smudge resolves every chain exactly once
/// (distinct groups have distinct chain keys), so a cache would add no
/// hits while pinning every intermediate chain tensor — up to
/// chain-depth × model size of heap — until the run ends.
pub fn smudge_metadata(
    access: &ObjectAccess,
    meta: &ModelMetadata,
    threads: usize,
) -> Result<Checkpoint> {
    smudge_metadata_opts(access, meta, threads, false)
}

/// Run the smudge filter, optionally with the per-run memoized
/// reconstruction cache (the benchmark ablation's toggle; useful to
/// real callers only when groups share chains, e.g. tied weights).
pub fn smudge_metadata_opts(
    access: &ObjectAccess,
    meta: &ModelMetadata,
    threads: usize,
    use_cache: bool,
) -> Result<Checkpoint> {
    // One negotiation + one pack for every object the model references
    // (instead of a lazy download per missing group during
    // reconstruction), chain-aware so held bases turn misses into deltas.
    access.prefetch_meta(meta)?;
    let cache = if use_cache {
        Some(ReconstructionCache::new())
    } else {
        None
    };
    let groups: Vec<(&String, &GroupMetadata)> = meta.groups.iter().collect();
    let tensors = par::try_par_map(&groups, threads, |_, (name, entry)| {
        checkout::reconstruct(access, entry, cache.as_ref())
            .with_context(|| format!("reconstructing parameter group '{name}'"))
    })?;
    Ok(groups
        .iter()
        .zip(tensors)
        .map(|((name, _), t)| ((*name).clone(), t))
        .collect())
}

impl FilterDriver for ThetaFilter {
    fn clean(&self, repo: &Repository, path: &str, working: &[u8]) -> Result<Vec<u8>> {
        let fmt = detect_format(Path::new(path), &working[..working.len().min(64)])
            .with_context(|| format!("no checkpoint format recognizes '{path}'"))?;
        let ck = fmt.load_bytes(working)?;
        let prior = match repo.prior_staged(path)? {
            Some(bytes) if ModelMetadata::is_metadata(&bytes) => {
                Some(ModelMetadata::from_bytes(&bytes)?)
            }
            _ => None,
        };
        let forced = repo.attributes()?.value_of(path, "theta-update");
        let access = ObjectAccess::for_repo(repo)?;
        let opts = CleanOptions {
            forced_update: forced,
            snapshot_depth: checkout::snapshot_depth_config(repo)?,
            ..Default::default()
        };
        let meta = clean_checkpoint_opts(&access, &ck, fmt.name(), prior.as_ref(), &opts)?;
        Ok(meta.to_bytes())
    }

    fn smudge(&self, repo: &Repository, path: &str, staged: &[u8]) -> Result<Vec<u8>> {
        let meta = ModelMetadata::from_bytes(staged)
            .with_context(|| format!("'{path}' is not a git-theta metadata file"))?;
        let access = ObjectAccess::for_repo(repo)?;
        let ck = smudge_metadata(&access, &meta, par::default_threads())?;
        let fmt = format_by_name(&meta.format)
            .with_context(|| format!("checkpoint format '{}' not registered", meta.format))?;
        fmt.save_bytes(&ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::tmp::TempDir;

    fn access(td: &TempDir) -> ObjectAccess {
        ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        }
    }

    fn random_ck(seed: u64) -> Checkpoint {
        let mut rng = Pcg64::new(seed);
        let mut ck = Checkpoint::new();
        for (name, m, n) in [("attn/q", 32usize, 32usize), ("attn/v", 32, 32), ("emb", 64, 16)] {
            let vals: Vec<f32> = (0..m * n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
            ck.insert(name, Tensor::from_f32(vec![m, n], vals).unwrap());
        }
        ck
    }

    #[test]
    fn missing_object_without_remote_is_a_clear_error() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let ghost = ObjRef {
            oid: Oid::of_bytes(b"never stored anywhere"),
            size: 5,
        };
        let err = acc.fetch(&ghost).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no remote is configured"), "{msg}");
    }

    #[test]
    fn clean_smudge_identity_fresh_model() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let ck = random_ck(1);
        let meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, 2).unwrap();
        // Fresh model: every group is dense.
        for g in meta.groups.values() {
            assert_eq!(g.update.kind, "dense");
            assert!(g.prev.is_none());
        }
        let back = smudge_metadata(&acc, &meta, 2).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn unchanged_groups_are_copied_not_restored() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let ck = random_ck(2);
        let v1 = clean_checkpoint(&acc, &ck, "safetensors", None, None, 2).unwrap();
        let usage_v1 = acc.store.disk_usage().unwrap();

        // Change only one group.
        let mut ck2 = ck.clone();
        let mut vals = ck2.get("attn/q").unwrap().to_f32_vec().unwrap();
        vals[0] += 0.5;
        ck2.insert("attn/q", Tensor::from_f32(vec![32, 32], vals).unwrap());

        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), None, 2).unwrap();
        // Unchanged groups share the exact same entry (same oids).
        assert_eq!(v2.groups["attn/v"], v1.groups["attn/v"]);
        assert_eq!(v2.groups["emb"], v1.groups["emb"]);
        assert_ne!(v2.groups["attn/q"], v1.groups["attn/q"]);
        // The only new object is the small sparse update.
        let growth = acc.store.disk_usage().unwrap() - usage_v1;
        assert!(growth < 1000, "store grew by {growth} bytes");
        assert_eq!(v2.groups["attn/q"].update.kind, "sparse");

        // Smudge reproduces the new checkpoint exactly.
        assert_eq!(smudge_metadata(&acc, &v2, 2).unwrap(), ck2);
        // And the old version still reconstructs.
        assert_eq!(smudge_metadata(&acc, &v1, 2).unwrap(), ck);
    }

    #[test]
    fn float_noise_below_threshold_is_ignored() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let ck = random_ck(3);
        let v1 = clean_checkpoint(&acc, &ck, "safetensors", None, None, 2).unwrap();

        // Perturb every group by ~1e-9 total L2 (simulated nondeterminism).
        let mut ck2 = Checkpoint::new();
        for (name, t) in ck.iter() {
            let mut vals = t.to_f32_vec().unwrap();
            let per = 1e-9f32 / (vals.len() as f32).sqrt();
            for v in vals.iter_mut() {
                *v += per;
            }
            ck2.insert(name.clone(), Tensor::from_f32(t.shape().to_vec(), vals).unwrap());
        }
        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), None, 2).unwrap();
        assert_eq!(v2, v1, "noise-level change must not create new versions");
    }

    #[test]
    fn shape_change_uses_trim() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let ck = random_ck(4);
        let v1 = clean_checkpoint(&acc, &ck, "safetensors", None, None, 2).unwrap();
        let mut ck2 = ck.clone();
        let trimmed = ck.get("emb").unwrap().take_rows(48).unwrap();
        ck2.insert("emb", trimmed);
        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), None, 2).unwrap();
        assert_eq!(v2.groups["emb"].update.kind, "trim");
        assert_eq!(v2.groups["emb"].own_bytes(), 0);
        assert_eq!(smudge_metadata(&acc, &v2, 2).unwrap(), ck2);
    }

    #[test]
    fn chained_incremental_updates_reconstruct() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let ck0 = random_ck(5);
        let v0 = clean_checkpoint(&acc, &ck0, "safetensors", None, None, 2).unwrap();

        // Sparse on top of dense, then sparse on top of sparse.
        let mut ck1 = ck0.clone();
        let mut vals = ck1.get("attn/q").unwrap().to_f32_vec().unwrap();
        vals[10] = 1.0;
        ck1.insert("attn/q", Tensor::from_f32(vec![32, 32], vals.clone()).unwrap());
        let v1 = clean_checkpoint(&acc, &ck1, "safetensors", Some(&v0), None, 2).unwrap();

        let mut ck2 = ck1.clone();
        vals[20] = -2.0;
        ck2.insert("attn/q", Tensor::from_f32(vec![32, 32], vals).unwrap());
        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), None, 2).unwrap();

        assert_eq!(v2.groups["attn/q"].chain_depth(), 3);
        assert_eq!(smudge_metadata(&acc, &v2, 2).unwrap(), ck2);
        assert_eq!(smudge_metadata(&acc, &v1, 2).unwrap(), ck1);
        assert_eq!(smudge_metadata(&acc, &v0, 2).unwrap(), ck0);
    }

    #[test]
    fn snapshot_depth_caps_chains() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let mut ck = random_ck(7);
        let mut metas = vec![clean_checkpoint(&acc, &ck, "safetensors", None, None, 2).unwrap()];
        let opts = CleanOptions {
            snapshot_depth: Some(3),
            threads: 2,
            ..Default::default()
        };
        for i in 0..10 {
            let mut vals = ck.get("attn/q").unwrap().to_f32_vec().unwrap();
            vals[i] += 1.0;
            ck.insert("attn/q", Tensor::from_f32(vec![32, 32], vals).unwrap());
            let prior = metas.last().unwrap().clone();
            let next =
                clean_checkpoint_opts(&acc, &ck, "safetensors", Some(&prior), &opts).unwrap();
            // The chain never exceeds the threshold; every version
            // still reconstructs the checkpoint exactly.
            assert!(next.groups["attn/q"].chain_depth() <= 3, "iteration {i}");
            assert_eq!(smudge_metadata(&acc, &next, 2).unwrap(), ck);
            metas.push(next);
        }
        // At least one re-anchor happened (depth reset to 1 = dense).
        assert!(metas.iter().any(|m| m.groups["attn/q"].prev.is_some()));
        assert!(metas[1..].iter().any(|m| m.groups["attn/q"].update.kind == "dense"));
        // Untouched groups carry forward byte-identically regardless.
        assert_eq!(metas[0].groups["attn/v"], metas[10].groups["attn/v"]);
    }

    #[test]
    fn forced_update_type_is_respected() {
        let td = TempDir::new("filter").unwrap();
        let acc = access(&td);
        let ck = random_ck(6);
        let v1 = clean_checkpoint(&acc, &ck, "safetensors", None, None, 2).unwrap();
        let mut ck2 = ck.clone();
        let mut vals = ck2.get("attn/q").unwrap().to_f32_vec().unwrap();
        vals[0] += 0.25;
        ck2.insert("attn/q", Tensor::from_f32(vec![32, 32], vals).unwrap());
        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), Some("dense"), 2).unwrap();
        assert_eq!(v2.groups["attn/q"].update.kind, "dense");
        // Dense chains don't reference prev.
        assert!(v2.groups["attn/q"].prev.is_none());
    }
}
