//! The checkout/commit compute engine: bounded-depth chains and
//! memoized reconstruction.
//!
//! The paper's smudge filter "resolves each group's update chain and
//! reconstructs full parameter values" (§3.2). Left unchecked, a
//! continually-trained model grows one incremental link per commit, so
//! checkout cost climbs linearly with training progress and the total
//! work over a run is quadratic. This module bounds and de-duplicates
//! that work:
//!
//! * **Chain snapshotting** — when a changed group's chain would exceed
//!   [`DEFAULT_SNAPSHOT_DEPTH`] (configurable via the
//!   `theta.snapshot-depth` repo config key), the clean filter stores
//!   the group densely instead of incrementally, re-anchoring the chain.
//!   The full tensor is already in memory at clean time, so the
//!   re-anchor costs one dense serialization and no reconstruction.
//!   [`snapshot_metadata`] applies the same re-anchoring to an existing
//!   model (the `git-theta snapshot` command).
//! * **Memoized reconstruction** ([`ReconstructionCache`]) — a per-run
//!   cache keyed by [`GroupMetadata::chain_key`], the content hash of
//!   an entry and its embedded base chain. Reconstruction is a pure
//!   function of exactly that content, so equal keys are guaranteed to
//!   mean equal tensors. `NeedsExactCheck` probes, incremental-update
//!   inference in the clean filter, and merge drivers resolving both
//!   sides of a common chain reuse each prefix instead of recomputing
//!   it.
//!
//! Unchanged groups are never re-anchored by the clean filter: their
//! metadata entries must carry forward byte-identically or every commit
//! would look fully changed (see docs/ARCHITECTURE.md, "Metadata-file
//! stability"). A chain written under a higher (or disabled) threshold
//! therefore keeps its depth until the group changes again or
//! `git-theta snapshot` is run.

use crate::gitcore::object::Oid;
use crate::gitcore::repo::Repository;
use crate::tensor::Tensor;
use crate::theta::filter::{store_payload, ObjectAccess};
use crate::theta::metadata::{GroupMetadata, ModelMetadata};
use crate::theta::serialize::deserialize_combined;
use crate::theta::updates::{update_type, UpdatePayload};
use crate::util::par;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Chain depth past which the clean filter re-anchors a changed group
/// as a dense entry. Override per repository with
/// `git-theta config theta.snapshot-depth <n|off>`.
pub const DEFAULT_SNAPSHOT_DEPTH: usize = 8;

/// Repo config key holding the snapshot depth threshold.
pub const SNAPSHOT_DEPTH_KEY: &str = "theta.snapshot-depth";

/// Parse a `theta.snapshot-depth` config value: a positive integer, or
/// `off`/`none`/`0` to disable automatic snapshotting.
pub fn parse_snapshot_depth(value: &str) -> Result<Option<usize>> {
    match value.trim() {
        "off" | "none" | "0" => Ok(None),
        s => {
            let n: usize = s
                .parse()
                .with_context(|| format!("bad {SNAPSHOT_DEPTH_KEY} value '{s}'"))?;
            Ok(Some(n))
        }
    }
}

/// The repository's snapshot-depth setting (default
/// [`DEFAULT_SNAPSHOT_DEPTH`]; `None` means snapshotting is off).
pub fn snapshot_depth_config(repo: &Repository) -> Result<Option<usize>> {
    match repo.config_get(SNAPSHOT_DEPTH_KEY)? {
        Some(v) => parse_snapshot_depth(&v),
        None => Ok(Some(DEFAULT_SNAPSHOT_DEPTH)),
    }
}

/// Per-run memoized reconstruction cache.
///
/// Maps [`GroupMetadata::chain_key`] → reconstructed tensor for every
/// *prefix* of a chain (the values below the entry being resolved).
/// Final chain values are returned owned and not cached: they are
/// unique to their group, so caching them would only add a copy.
///
/// The cache is `Sync` (a mutex-guarded map plus relaxed counters) and
/// is shared across the parallel per-group workers of one run. It is
/// wired in only where a chain can genuinely be resolved more than
/// once per run — the clean filter's `NeedsExactCheck` probes and
/// incremental inference — and is an explicit opt-in elsewhere
/// ([`smudge_metadata_opts`](crate::theta::filter::smudge_metadata_opts)):
/// entries pin full tensors until the run ends, so enabling it on a
/// path with no re-resolution costs up to chain-depth × model size of
/// heap for zero hits. It is intentionally scoped to a run, never the
/// process, for the same reason.
pub struct ReconstructionCache {
    entries: Mutex<HashMap<Oid, Arc<Tensor>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes: AtomicU64,
}

impl Default for ReconstructionCache {
    fn default() -> ReconstructionCache {
        ReconstructionCache::new()
    }
}

impl ReconstructionCache {
    /// An empty cache.
    pub fn new() -> ReconstructionCache {
        ReconstructionCache {
            entries: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    fn lookup(&self, key: &Oid) -> Option<Arc<Tensor>> {
        let hit = self.entries.lock().unwrap().get(key).cloned();
        match &hit {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    fn insert(&self, key: Oid, value: Arc<Tensor>) {
        let mut map = self.entries.lock().unwrap();
        if map.insert(key, value.clone()).is_none() {
            self.bytes.fetch_add(value.nbytes() as u64, Ordering::Relaxed);
        }
    }

    /// Lookups answered from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to reconstruct.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Total tensor bytes currently held by the cache.
    pub fn cached_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// Apply one chain entry on top of an already-reconstructed base.
fn apply_entry(
    access: &ObjectAccess,
    entry: &GroupMetadata,
    prev: Option<&Tensor>,
) -> Result<Tensor> {
    let tensors = match entry.update.objects.get("data") {
        Some(obj) => deserialize_combined(&access.fetch(obj)?)?,
        None => Default::default(),
    };
    let payload = UpdatePayload {
        kind: entry.update.kind.clone(),
        tensors,
        extra: entry.update.extra.clone(),
    };
    let u = update_type(&entry.update.kind)
        .with_context(|| format!("unknown update type '{}'", entry.update.kind))?;
    u.apply(&payload, prev)
}

/// Reconstruct a chain prefix, memoized in `cache` when provided.
fn reconstruct_prefix(
    access: &ObjectAccess,
    entry: &GroupMetadata,
    cache: Option<&ReconstructionCache>,
) -> Result<Arc<Tensor>> {
    let key = cache.map(|_| entry.chain_key());
    if let (Some(c), Some(k)) = (cache, &key) {
        if let Some(t) = c.lookup(k) {
            return Ok(t);
        }
    }
    let prev = match &entry.prev {
        Some(p) => Some(reconstruct_prefix(access, p, cache)?),
        None => None,
    };
    let t = Arc::new(apply_entry(access, entry, prev.as_deref())?);
    if let (Some(c), Some(k)) = (cache, key) {
        c.insert(k, t.clone());
    }
    Ok(t)
}

/// Reconstruct a group's full values from its metadata entry, resolving
/// the incremental chain (paper §3.2 "Checking Out a Model").
///
/// With a cache, every chain *prefix* is looked up by content hash
/// before being recomputed, so callers resolving overlapping chains —
/// a `NeedsExactCheck` probe followed by incremental inference, the two
/// sides of a merge, repeated smudges in one process — pay for each
/// prefix once. Without a cache this is the plain linear resolution.
pub fn reconstruct(
    access: &ObjectAccess,
    entry: &GroupMetadata,
    cache: Option<&ReconstructionCache>,
) -> Result<Tensor> {
    let prev = match &entry.prev {
        Some(p) => Some(reconstruct_prefix(access, p, cache)?),
        None => None,
    };
    apply_entry(access, entry, prev.as_deref())
}

/// `rtol` of the exact `allclose` fallback (numpy's default; paper:
/// "weights that have a Euclidean distance ∈ [1e-8, 1e-6] are checked
/// with np.allclose"). Shared by the clean filter's change probe and
/// the merge/diff engines' change-skipping.
pub const EXACT_RTOL: f64 = 1e-5;

/// `atol` of the exact `allclose` fallback (numpy's default).
pub const EXACT_ATOL: f64 = 1e-8;

/// Exact value-equality fallback for the LSH `NeedsExactCheck` band:
/// reconstruct both entries (through a shared cache when given — the
/// two chains usually share a prefix) and compare with `allclose`
/// under [`EXACT_RTOL`]/[`EXACT_ATOL`].
///
/// Shape or dtype mismatches are `false` without reconstructing.
/// This is the expensive half of the paper's two-tier change check;
/// callers reach it only for the rare ambiguous band, never for
/// signatures the LSH already classifies.
pub fn values_equal_exact(
    access: &ObjectAccess,
    a: &GroupMetadata,
    b: &GroupMetadata,
    cache: Option<&ReconstructionCache>,
) -> Result<bool> {
    if a.tensor.shape != b.tensor.shape || a.tensor.dtype != b.tensor.dtype {
        return Ok(false);
    }
    let ta = reconstruct(access, a, cache)?;
    let tb = reconstruct(access, b, cache)?;
    Ok(crate::tensor::allclose(&ta, &tb, EXACT_RTOL, EXACT_ATOL)?)
}

/// What [`snapshot_metadata`] did to a model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SnapshotReport {
    /// Parameter groups in the model.
    pub groups: usize,
    /// Groups re-anchored as fresh dense entries.
    pub reanchored: usize,
    /// Deepest chain before re-anchoring.
    pub max_depth_before: usize,
}

/// Re-anchor every chained group of `meta` as a dense entry.
///
/// Each group with `chain_depth() > 1` (or a non-dense terminal entry)
/// is reconstructed once and stored densely, resetting its chain depth
/// to 1. Reconstruction is uncached: every chain resolves exactly once
/// here, so memoization would only pin each intermediate tensor until
/// the whole model is done. Tensor values are untouched, so the smudge
/// output of the returned metadata is byte-for-byte identical to the
/// input's, and the stored LSH signatures remain valid for future
/// change detection.
pub fn snapshot_metadata(
    access: &ObjectAccess,
    meta: &ModelMetadata,
    threads: usize,
) -> Result<(ModelMetadata, SnapshotReport)> {
    access.prefetch(&meta.all_oids())?;
    let groups: Vec<(&String, &GroupMetadata)> = meta.groups.iter().collect();
    let entries = par::try_par_map(&groups, threads, |_, (name, entry)| {
        snapshot_group(access, entry)
            .with_context(|| format!("snapshotting parameter group '{name}'"))
    })?;

    let mut out = ModelMetadata::new(meta.format.clone());
    let mut report = SnapshotReport {
        groups: groups.len(),
        ..Default::default()
    };
    for ((name, old), (entry, reanchored)) in groups.iter().zip(entries) {
        report.max_depth_before = report.max_depth_before.max(old.chain_depth());
        if reanchored {
            report.reanchored += 1;
        }
        out.groups.insert((*name).clone(), entry);
    }
    Ok((out, report))
}

fn snapshot_group(access: &ObjectAccess, entry: &GroupMetadata) -> Result<(GroupMetadata, bool)> {
    let already_dense = entry.prev.is_none()
        && update_type(&entry.update.kind).map_or(false, |u| !u.requires_prev());
    if already_dense {
        // Keep the entry (and its oids) byte-identical: a no-op
        // snapshot must not make the group look changed to Git.
        return Ok((entry.clone(), false));
    }
    let full = reconstruct(access, entry, None)?;
    let dense = update_type("dense")
        .context("dense update type not registered")?
        .infer(None, &full)?
        .context("dense update cannot represent tensor")?;
    let new_entry = store_payload(access, &full, entry.tensor.lsh.clone(), dense, None)?;
    Ok((new_entry, true))
}

/// Decide whether a changed group's prospective chain must be
/// re-anchored: true when appending one incremental link on top of
/// `prior` would push the depth past `limit`.
pub fn should_snapshot(prior: &GroupMetadata, limit: Option<usize>) -> bool {
    match limit {
        Some(limit) => prior.chain_depth() + 1 > limit,
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::lfs::LfsStore;
    use crate::theta::filter::{clean_checkpoint, smudge_metadata};
    use crate::util::rng::Pcg64;
    use crate::util::tmp::TempDir;

    fn access(td: &TempDir) -> ObjectAccess {
        ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        }
    }

    fn random_ck(seed: u64, n: usize) -> Checkpoint {
        let mut rng = Pcg64::new(seed);
        let vals: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![n], vals).unwrap());
        ck
    }

    /// Build a chain of `depth` versions by touching one element per
    /// version (sparse updates all the way down).
    fn chained(acc: &ObjectAccess, depth: usize) -> (Vec<ModelMetadata>, Checkpoint) {
        let mut ck = random_ck(1, 256);
        let mut metas = vec![clean_checkpoint(acc, &ck, "safetensors", None, None, 1).unwrap()];
        for i in 1..depth {
            let mut vals = ck.get("w").unwrap().to_f32_vec().unwrap();
            vals[i % 256] += 1.0;
            ck.insert("w", Tensor::from_f32(vec![256], vals).unwrap());
            let prior = metas.last().unwrap().clone();
            let next = crate::theta::filter::clean_checkpoint_opts(
                acc,
                &ck,
                "safetensors",
                Some(&prior),
                &crate::theta::filter::CleanOptions {
                    snapshot_depth: None,
                    threads: 1,
                    ..Default::default()
                },
            )
            .unwrap();
            metas.push(next);
        }
        (metas, ck)
    }

    #[test]
    fn parse_snapshot_depth_values() {
        assert_eq!(parse_snapshot_depth("8").unwrap(), Some(8));
        assert_eq!(parse_snapshot_depth(" 3 ").unwrap(), Some(3));
        assert_eq!(parse_snapshot_depth("off").unwrap(), None);
        assert_eq!(parse_snapshot_depth("none").unwrap(), None);
        assert_eq!(parse_snapshot_depth("0").unwrap(), None);
        assert!(parse_snapshot_depth("soon").is_err());
    }

    #[test]
    fn cache_reuses_prefixes() {
        let td = TempDir::new("checkout").unwrap();
        let acc = access(&td);
        let (metas, ck) = chained(&acc, 6);
        let deep = &metas.last().unwrap().groups["w"];
        assert_eq!(deep.chain_depth(), 6);

        let cache = ReconstructionCache::new();
        let a = reconstruct(&acc, deep, Some(&cache)).unwrap();
        assert_eq!(&a, ck.get("w").unwrap());
        let misses_first = cache.misses();
        assert_eq!(cache.hits(), 0);
        assert_eq!(misses_first, 5); // one per prefix level

        // Second resolution of the same chain: one hit, no new misses.
        let b = reconstruct(&acc, deep, Some(&cache)).unwrap();
        assert_eq!(a, b);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), misses_first);
        assert!(cache.cached_bytes() >= 256 * 4);
    }

    #[test]
    fn snapshot_preserves_values_and_resets_depth() {
        let td = TempDir::new("checkout").unwrap();
        let acc = access(&td);
        let (metas, ck) = chained(&acc, 9);
        let deep = metas.last().unwrap();
        assert_eq!(deep.groups["w"].chain_depth(), 9);

        let (snap, report) = snapshot_metadata(&acc, deep, 1).unwrap();
        assert_eq!(report.groups, 1);
        assert_eq!(report.reanchored, 1);
        assert_eq!(report.max_depth_before, 9);
        assert_eq!(snap.groups["w"].chain_depth(), 1);
        assert_eq!(snap.groups["w"].update.kind, "dense");
        // LSH signature carried over; smudge output byte-for-byte equal.
        assert_eq!(snap.groups["w"].tensor, deep.groups["w"].tensor);
        let a = smudge_metadata(&acc, deep, 1).unwrap();
        let b = smudge_metadata(&acc, &snap, 1).unwrap();
        assert_eq!(a, b);
        assert_eq!(b, ck);

        // Snapshotting a dense model is a no-op with identical entries.
        let (snap2, report2) = snapshot_metadata(&acc, &snap, 1).unwrap();
        assert_eq!(report2.reanchored, 0);
        assert_eq!(snap2, snap);
    }

    #[test]
    fn should_snapshot_threshold() {
        let td = TempDir::new("checkout").unwrap();
        let acc = access(&td);
        let (metas, _) = chained(&acc, 4);
        let e = &metas.last().unwrap().groups["w"]; // depth 4
        assert!(!should_snapshot(e, None));
        assert!(!should_snapshot(e, Some(5)));
        assert!(should_snapshot(e, Some(4)));
        assert!(should_snapshot(e, Some(2)));
    }
}
