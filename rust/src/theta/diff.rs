//! The Git-Theta diff driver (paper §3.2 "Diffing Models").
//!
//! Where Git LFS can only say two checkpoints are "not bitwise
//! identical", this driver reports which parameter groups were added,
//! removed, or modified, with shapes, dtypes, update types, and the
//! storage cost of each change.
//!
//! Classification is metadata-only and never reconstructs a tensor:
//! unchanged groups compare byte-identically, and groups whose
//! metadata changed but whose LSH signatures prove the *values*
//! unchanged (e.g. a `git-theta snapshot` re-anchor) are reported as
//! re-anchored rather than modified. The optional **exact** mode
//! ([`exact_diff`], CLI `git-theta diff --exact`) reconstructs only
//! the genuinely modified groups — both sides in parallel, chains
//! deduplicated through a shared [`ReconstructionCache`], every
//! missing object prefetched as one pack — so its cost scales with
//! the changed parameter set, not with model size.

use crate::gitcore::drivers::DiffDriver;
use crate::gitcore::object::Oid;
use crate::gitcore::repo::Repository;
use crate::tensor::euclidean_distance;
use crate::theta::checkout::{self, ReconstructionCache};
use crate::theta::filter::ObjectAccess;
use crate::theta::metadata::{GroupMetadata, ModelMetadata};
use crate::util::{humansize, par};
use anyhow::Result;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};

/// The `diff=theta` driver.
pub struct ThetaDiff;

/// Structured diff between two metadata versions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelDiff {
    /// Groups present only in the new version.
    pub added: Vec<String>,
    /// Groups present only in the old version.
    pub removed: Vec<String>,
    /// Groups whose metadata *and* values changed.
    pub modified: Vec<String>,
    /// Groups whose metadata changed but whose LSH signatures prove
    /// the values unchanged (e.g. a snapshot re-anchor). Never worth
    /// reconstructing.
    pub reanchored: Vec<String>,
    /// Groups carried forward byte-identically.
    pub unchanged: usize,
}

impl ModelDiff {
    /// Compute the group-level diff between two metadata versions.
    /// Pure metadata/LSH comparison — no tensor is ever reconstructed;
    /// the ambiguous LSH band conservatively classifies as modified.
    pub fn between(old: Option<&ModelMetadata>, new: Option<&ModelMetadata>) -> ModelDiff {
        let empty = ModelMetadata::new("");
        let old = old.unwrap_or(&empty);
        let new = new.unwrap_or(&empty);
        Self::classify(old, new, |_, _| Ok(false))
            .expect("conservative ambiguity resolver cannot fail")
    }

    /// Like [`ModelDiff::between`], but groups whose LSH comparison
    /// lands in the ambiguous `NeedsExactCheck` band are settled by
    /// the exact fallback — reconstruct both sides (through `cache`)
    /// and compare with `allclose` — instead of conservatively
    /// reported as modified. A numerically identical rewrite whose
    /// distance estimate sits in [1e-8, 1e-6] therefore classifies as
    /// re-anchored, and `--exact` never computes an L2 for it.
    pub fn between_exact(
        access: &ObjectAccess,
        old: &ModelMetadata,
        new: &ModelMetadata,
        cache: Option<&ReconstructionCache>,
    ) -> Result<ModelDiff> {
        Self::classify(old, new, |o, n| checkout::values_equal_exact(access, o, n, cache))
    }

    /// The one classification walk both modes share; `ambiguous_equal`
    /// decides the LSH `NeedsExactCheck` band (constant `false` for
    /// the metadata-only mode, the exact reconstruct + `allclose`
    /// fallback for `--exact`).
    fn classify(
        old: &ModelMetadata,
        new: &ModelMetadata,
        mut ambiguous_equal: impl FnMut(&GroupMetadata, &GroupMetadata) -> Result<bool>,
    ) -> Result<ModelDiff> {
        use crate::theta::metadata::ValueMatch;
        let mut diff = ModelDiff::default();
        for (name, entry) in &new.groups {
            match old.groups.get(name) {
                None => diff.added.push(name.clone()),
                Some(o) if o == entry => diff.unchanged += 1,
                Some(o) => match o.values_verdict(entry) {
                    ValueMatch::Equal => diff.reanchored.push(name.clone()),
                    ValueMatch::Ambiguous if ambiguous_equal(o, entry)? => {
                        diff.reanchored.push(name.clone())
                    }
                    _ => diff.modified.push(name.clone()),
                },
            }
        }
        for name in old.groups.keys() {
            if !new.groups.contains_key(name) {
                diff.removed.push(name.clone());
            }
        }
        Ok(diff)
    }

    /// True when nothing changed (not even a value-preserving
    /// re-anchor, which still rewrites the metadata Git versions).
    pub fn is_empty(&self) -> bool {
        self.added.is_empty()
            && self.removed.is_empty()
            && self.modified.is_empty()
            && self.reanchored.is_empty()
    }
}

fn describe(entry: &GroupMetadata) -> String {
    format!(
        "{:?} {} update={} stored={}",
        entry.tensor.shape,
        entry.tensor.dtype,
        entry.update.kind,
        humansize::bytes(entry.own_bytes())
    )
}

/// Render a human-readable model diff.
pub fn render_diff(
    path: &str,
    old: Option<&ModelMetadata>,
    new: Option<&ModelMetadata>,
) -> String {
    let diff = ModelDiff::between(old, new);
    let mut out = String::new();
    let _ = writeln!(out, "model {path}");
    if diff.is_empty() {
        let _ = writeln!(out, "  parameters unchanged ({} groups)", diff.unchanged);
        return out;
    }
    for name in &diff.added {
        let entry = &new.unwrap().groups[name];
        let _ = writeln!(out, "  + added    {name}  [{}]", describe(entry));
    }
    for name in &diff.removed {
        let entry = &old.unwrap().groups[name];
        let _ = writeln!(out, "  - removed  {name}  [{}]", describe(entry));
    }
    for name in &diff.modified {
        let o = &old.unwrap().groups[name];
        let n = &new.unwrap().groups[name];
        if o.tensor.shape != n.tensor.shape {
            let _ = writeln!(
                out,
                "  ~ modified {name}  shape {:?} -> {:?} [{}]",
                o.tensor.shape,
                n.tensor.shape,
                describe(n)
            );
        } else {
            let dist = n.tensor.lsh.distance_estimate(&o.tensor.lsh);
            let _ = writeln!(
                out,
                "  ~ modified {name}  [{}] (L2 distance ~{dist:.3e})",
                describe(n)
            );
        }
    }
    for name in &diff.reanchored {
        let n = &new.unwrap().groups[name];
        let _ = writeln!(
            out,
            "  = re-anchored {name}  [{}] (values unchanged)",
            describe(n)
        );
    }
    let _ = writeln!(
        out,
        "  = {} groups unchanged (stored as references)",
        diff.unchanged
    );
    out
}

/// One exact value-level delta from [`exact_diff`].
#[derive(Debug, Clone, PartialEq)]
pub struct ValueDelta {
    /// Parameter-group name.
    pub group: String,
    /// Exact Euclidean distance between the two reconstructed values;
    /// `None` when the shapes/dtypes differ (no distance is defined).
    pub l2: Option<f64>,
}

/// Exact value-level diff: reconstruct *only* the modified groups and
/// compute their true Euclidean distance.
///
/// Cost scales with the changed parameter set: groups the metadata or
/// LSH comparison already proves unchanged (including re-anchors) are
/// never reconstructed — their objects are not even fetched. Modified
/// groups reconstruct on [`par`] workers, both sides sharing one
/// [`ReconstructionCache`] (old and new chains usually share a
/// prefix), with every missing object prefetched up front as one pack.
pub fn exact_diff(
    access: &ObjectAccess,
    old: &ModelMetadata,
    new: &ModelMetadata,
    threads: usize,
) -> Result<Vec<ValueDelta>> {
    let cache = ReconstructionCache::new();
    // Exact-mode classification: ambiguous LSH bands get the allclose
    // fallback here (their reconstructions land in the shared cache,
    // so nothing is decoded twice), and groups it proves value-equal
    // drop out of the L2 stage entirely.
    let diff = ModelDiff::between_exact(access, old, new, Some(&cache))?;
    let pairs: Vec<(&String, &GroupMetadata, &GroupMetadata)> = diff
        .modified
        .iter()
        .map(|name| {
            let o = &old.groups[name];
            let n = &new.groups[name];
            (name, o, n)
        })
        .collect();

    // One negotiation + one pack for exactly the objects the modified
    // groups' chains reference.
    let mut oids: Vec<Oid> = Vec::new();
    for (_, o, n) in &pairs {
        o.all_oids(&mut oids);
        n.all_oids(&mut oids);
    }
    oids.sort();
    oids.dedup();
    access.prefetch(&oids)?;

    par::try_par_map(&pairs, threads, |_, pair| {
        let (name, o, n) = *pair;
        if o.tensor.shape != n.tensor.shape || o.tensor.dtype != n.tensor.dtype {
            return Ok(ValueDelta {
                group: name.clone(),
                l2: None,
            });
        }
        let a = checkout::reconstruct(access, o, Some(&cache))?;
        let b = checkout::reconstruct(access, n, Some(&cache))?;
        Ok(ValueDelta {
            group: name.clone(),
            l2: Some(euclidean_distance(&a, &b)?),
        })
    })
}

/// Render the exact value-level distances appended in `--exact` mode.
pub fn render_exact(deltas: &[ValueDelta]) -> String {
    let mut out = String::new();
    for d in deltas {
        match d.l2 {
            Some(l2) => {
                let _ = writeln!(out, "  exact: {}  L2 distance = {l2:.6e}", d.group);
            }
            None => {
                let _ = writeln!(out, "  exact: {}  (shape changed; no distance)", d.group);
            }
        }
    }
    out
}

/// Process-wide `--exact` toggle for the registered diff driver (the
/// driver registry's `diff` hook carries no option channel; the CLI
/// sets this around a `git-theta diff --exact` invocation, mirroring
/// `lfs::batch::set_per_object_mode`).
static EXACT_MODE: AtomicBool = AtomicBool::new(false);

/// Enable or disable exact (value-level) rendering for subsequent
/// [`ThetaDiff`] invocations in this process.
pub fn set_exact_diff(on: bool) {
    EXACT_MODE.store(on, Ordering::Relaxed);
}

/// Whether exact (value-level) rendering is currently enabled.
pub fn exact_diff_enabled() -> bool {
    EXACT_MODE.load(Ordering::Relaxed)
}

impl DiffDriver for ThetaDiff {
    fn diff(
        &self,
        repo: &Repository,
        path: &str,
        old: Option<&[u8]>,
        new: Option<&[u8]>,
    ) -> Result<String> {
        let parse = |bytes: Option<&[u8]>| -> Option<ModelMetadata> {
            bytes.and_then(|b| ModelMetadata::from_bytes(b).ok())
        };
        let old = parse(old);
        let new = parse(new);
        let mut out = render_diff(path, old.as_ref(), new.as_ref());
        if exact_diff_enabled() {
            if let (Some(o), Some(n)) = (&old, &new) {
                let access = ObjectAccess::for_repo(repo)?;
                let deltas = exact_diff(&access, o, n, par::default_threads())?;
                out.push_str(&render_exact(&deltas));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::lfs::LfsStore;
    use crate::tensor::Tensor;
    use crate::theta::filter::{clean_checkpoint, ObjectAccess};
    use crate::util::tmp::TempDir;

    fn make_versions_in(td: &TempDir) -> (ObjectAccess, ModelMetadata, ModelMetadata) {
        let acc = ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        };
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![4, 4], vec![0.5; 16]).unwrap());
        ck.insert("b", Tensor::from_f32(vec![4], vec![0.1; 4]).unwrap());
        let v1 = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();

        let mut ck2 = Checkpoint::new();
        let mut w = vec![0.5f32; 16];
        w[3] = 9.0;
        ck2.insert("w", Tensor::from_f32(vec![4, 4], w).unwrap());
        ck2.insert("new_head", Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap());
        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), None, 1).unwrap();
        (acc, v1, v2)
    }

    fn make_versions() -> (ModelMetadata, ModelMetadata) {
        let td = TempDir::new("diff").unwrap();
        let (_, v1, v2) = make_versions_in(&td);
        (v1, v2)
    }

    #[test]
    fn structured_diff() {
        let (v1, v2) = make_versions();
        let diff = ModelDiff::between(Some(&v1), Some(&v2));
        assert_eq!(diff.added, vec!["new_head"]);
        assert_eq!(diff.removed, vec!["b"]);
        assert_eq!(diff.modified, vec!["w"]);
        assert!(diff.reanchored.is_empty());
        assert_eq!(diff.unchanged, 0);
    }

    #[test]
    fn identical_versions_empty_diff() {
        let (v1, _) = make_versions();
        let diff = ModelDiff::between(Some(&v1), Some(&v1));
        assert!(diff.is_empty());
        assert_eq!(diff.unchanged, 2);
    }

    #[test]
    fn rendered_diff_mentions_groups_and_types() {
        let (v1, v2) = make_versions();
        let text = render_diff("model.safetensors", Some(&v1), Some(&v2));
        assert!(text.contains("+ added    new_head"));
        assert!(text.contains("- removed  b"));
        assert!(text.contains("~ modified w"));
        assert!(text.contains("update="));
        assert!(text.contains("L2 distance"));
    }

    #[test]
    fn new_file_diff() {
        let (v1, _) = make_versions();
        let diff = ModelDiff::between(None, Some(&v1));
        assert_eq!(diff.added.len(), 2);
    }

    #[test]
    fn reanchor_classified_by_lsh_not_as_modified() {
        let td = TempDir::new("diff-reanchor").unwrap();
        let acc = ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        };
        // Grow a chain, then snapshot: metadata changes, values don't.
        let deep_opts = crate::theta::filter::CleanOptions {
            snapshot_depth: None,
            threads: 1,
            ..Default::default()
        };
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![8], vec![0.25; 8]).unwrap());
        let mut meta =
            crate::theta::filter::clean_checkpoint_opts(&acc, &ck, "safetensors", None, &deep_opts)
                .unwrap();
        for i in 0..3 {
            let mut vals = ck.get("w").unwrap().to_f32_vec().unwrap();
            vals[i] += 1.0;
            ck.insert("w", Tensor::from_f32(vec![8], vals).unwrap());
            meta = crate::theta::filter::clean_checkpoint_opts(
                &acc,
                &ck,
                "safetensors",
                Some(&meta),
                &deep_opts,
            )
            .unwrap();
        }
        let (snapped, report) = crate::theta::checkout::snapshot_metadata(&acc, &meta, 1).unwrap();
        assert_eq!(report.reanchored, 1);

        let diff = ModelDiff::between(Some(&meta), Some(&snapped));
        assert_eq!(diff.reanchored, vec!["w"]);
        assert!(diff.modified.is_empty());
        assert!(!diff.is_empty()); // the metadata Git sees did change
        let text = render_diff("m", Some(&meta), Some(&snapped));
        assert!(text.contains("re-anchored w"), "{text}");

        // Exact mode has nothing to reconstruct for a pure re-anchor.
        let deltas = exact_diff(&acc, &meta, &snapped, 1).unwrap();
        assert!(deltas.is_empty());
    }

    #[test]
    fn exact_diff_distances_and_shape_changes() {
        let td = TempDir::new("diff-exact").unwrap();
        let (acc, v1, v2) = make_versions_in(&td);
        let deltas = exact_diff(&acc, &v1, &v2, 2).unwrap();
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].group, "w");
        // One element moved 0.5 -> 9.0: exact L2 is 8.5.
        let l2 = deltas[0].l2.unwrap();
        assert!((l2 - 8.5).abs() < 1e-6, "{l2}");
        let text = render_exact(&deltas);
        assert!(text.contains("L2 distance = 8.5"), "{text}");

        // Shape changes are reported without a distance.
        let mut ck3 = Checkpoint::new();
        ck3.insert("w", Tensor::from_f32(vec![2, 4], vec![0.5; 8]).unwrap());
        let v3 = clean_checkpoint(&acc, &ck3, "safetensors", Some(&v1), None, 1).unwrap();
        let deltas = exact_diff(&acc, &v1, &v3, 1).unwrap();
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].l2, None);
        assert!(render_exact(&deltas).contains("shape changed"));
    }

    #[test]
    fn ambiguous_band_reclassifies_as_reanchored_in_exact_mode() {
        use crate::theta::filter::store_payload;
        use crate::theta::lsh::{LshSignature, LshVerdict};
        use crate::theta::updates::UpdatePayload;
        use crate::util::rng::Pcg64;

        // Deterministically probe seeds for a pair in the ambiguous
        // LSH band (see the matching merge-engine test).
        let n = 4096usize;
        let (base, near) = (0..200u64)
            .find_map(|seed| {
                let mut rng = Pcg64::new(2000 + seed);
                let base: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 2e-3).collect();
                let per = 3e-8f32 / (n as f32).sqrt();
                let near: Vec<f32> = base.iter().map(|v| v + per).collect();
                let a = LshSignature::of_values(&base);
                let b = LshSignature::of_values(&near);
                (a.compare(&b) == LshVerdict::NeedsExactCheck).then(|| (base, near))
            })
            .expect("no ambiguous pair in 200 deterministic seeds");

        let td = TempDir::new("diff-ambiguous").unwrap();
        let acc = ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        };
        let dense = |vals: &[f32]| {
            let t = Tensor::from_f32(vec![vals.len()], vals.to_vec()).unwrap();
            let sig = LshSignature::of_tensor(&t).unwrap();
            let mut payload = UpdatePayload::new("dense");
            payload.tensors.insert("values".into(), t.clone());
            store_payload(&acc, &t, sig, payload, None).unwrap()
        };
        let mut v1 = ModelMetadata::new("safetensors");
        v1.groups.insert("w".into(), dense(&base));
        let mut v2 = ModelMetadata::new("safetensors");
        v2.groups.insert("w".into(), dense(&near));

        // Metadata-only classification stays conservative: modified.
        let plain = ModelDiff::between(Some(&v1), Some(&v2));
        assert_eq!(plain.modified, vec!["w"]);
        assert!(plain.reanchored.is_empty());

        // Exact mode settles the band: re-anchored (skip count
        // improves), and the L2 stage has nothing left to reconstruct.
        let exact = ModelDiff::between_exact(&acc, &v1, &v2, None).unwrap();
        assert_eq!(exact.reanchored, vec!["w"]);
        assert!(exact.modified.is_empty());
        let deltas = exact_diff(&acc, &v1, &v2, 1).unwrap();
        assert!(deltas.is_empty());
    }

    #[test]
    fn exact_diff_never_touches_unchanged_groups() {
        let td = TempDir::new("diff-skip").unwrap();
        let acc = ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        };
        // Three groups; only "w" changes between versions.
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![8], vec![0.5; 8]).unwrap());
        ck.insert("kept_a", Tensor::from_f32(vec![8], vec![1.0; 8]).unwrap());
        ck.insert("kept_b", Tensor::from_f32(vec![4], vec![2.0; 4]).unwrap());
        let v1 = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();
        let mut ck2 = ck.clone();
        let mut w = vec![0.5f32; 8];
        w[0] = 3.5;
        ck2.insert("w", Tensor::from_f32(vec![8], w).unwrap());
        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), None, 1).unwrap();
        let diff = ModelDiff::between(Some(&v1), Some(&v2));
        assert_eq!(diff.unchanged, 2);
        assert_eq!(diff.modified, vec!["w"]);

        // Delete every object the unchanged groups reference. If
        // exact_diff reconstructed them, the store would report a
        // missing object and the whole diff would fail.
        let mut changed: Vec<crate::gitcore::object::Oid> = Vec::new();
        v1.groups["w"].all_oids(&mut changed);
        v2.groups["w"].all_oids(&mut changed);
        for oid in acc.store.list().unwrap() {
            if !changed.contains(&oid) {
                assert!(acc.store.delete(&oid).unwrap());
            }
        }
        let deltas = exact_diff(&acc, &v1, &v2, 2).unwrap();
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].group, "w");
        assert!((deltas[0].l2.unwrap() - 3.0).abs() < 1e-6);
    }
}
