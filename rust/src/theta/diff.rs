//! The Git-Theta diff driver (paper §3.2 "Diffing Models").
//!
//! Where Git LFS can only say two checkpoints are "not bitwise
//! identical", this driver reports which parameter groups were added,
//! removed, or modified, with shapes, dtypes, update types, and the
//! storage cost of each change.

use crate::gitcore::drivers::DiffDriver;
use crate::gitcore::repo::Repository;
use crate::theta::metadata::{GroupMetadata, ModelMetadata};
use crate::util::humansize;
use anyhow::Result;
use std::fmt::Write as _;

/// The `diff=theta` driver.
pub struct ThetaDiff;

/// Structured diff between two metadata versions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelDiff {
    pub added: Vec<String>,
    pub removed: Vec<String>,
    pub modified: Vec<String>,
    pub unchanged: usize,
}

impl ModelDiff {
    /// Compute the group-level diff between two metadata versions.
    pub fn between(old: Option<&ModelMetadata>, new: Option<&ModelMetadata>) -> ModelDiff {
        let empty = ModelMetadata::new("");
        let old = old.unwrap_or(&empty);
        let new = new.unwrap_or(&empty);
        let mut diff = ModelDiff::default();
        for (name, entry) in &new.groups {
            match old.groups.get(name) {
                None => diff.added.push(name.clone()),
                Some(o) if o != entry => diff.modified.push(name.clone()),
                Some(_) => diff.unchanged += 1,
            }
        }
        for name in old.groups.keys() {
            if !new.groups.contains_key(name) {
                diff.removed.push(name.clone());
            }
        }
        diff
    }

    pub fn is_empty(&self) -> bool {
        self.added.is_empty() && self.removed.is_empty() && self.modified.is_empty()
    }
}

fn describe(entry: &GroupMetadata) -> String {
    format!(
        "{:?} {} update={} stored={}",
        entry.tensor.shape,
        entry.tensor.dtype,
        entry.update.kind,
        humansize::bytes(entry.own_bytes())
    )
}

/// Render a human-readable model diff.
pub fn render_diff(
    path: &str,
    old: Option<&ModelMetadata>,
    new: Option<&ModelMetadata>,
) -> String {
    let diff = ModelDiff::between(old, new);
    let mut out = String::new();
    let _ = writeln!(out, "model {path}");
    if diff.is_empty() {
        let _ = writeln!(out, "  parameters unchanged ({} groups)", diff.unchanged);
        return out;
    }
    for name in &diff.added {
        let entry = &new.unwrap().groups[name];
        let _ = writeln!(out, "  + added    {name}  [{}]", describe(entry));
    }
    for name in &diff.removed {
        let entry = &old.unwrap().groups[name];
        let _ = writeln!(out, "  - removed  {name}  [{}]", describe(entry));
    }
    for name in &diff.modified {
        let o = &old.unwrap().groups[name];
        let n = &new.unwrap().groups[name];
        if o.tensor.shape != n.tensor.shape {
            let _ = writeln!(
                out,
                "  ~ modified {name}  shape {:?} -> {:?} [{}]",
                o.tensor.shape,
                n.tensor.shape,
                describe(n)
            );
        } else {
            let dist = n.tensor.lsh.distance_estimate(&o.tensor.lsh);
            let _ = writeln!(
                out,
                "  ~ modified {name}  [{}] (L2 distance ~{dist:.3e})",
                describe(n)
            );
        }
    }
    let _ = writeln!(
        out,
        "  = {} groups unchanged (stored as references)",
        diff.unchanged
    );
    out
}

impl DiffDriver for ThetaDiff {
    fn diff(
        &self,
        _repo: &Repository,
        path: &str,
        old: Option<&[u8]>,
        new: Option<&[u8]>,
    ) -> Result<String> {
        let parse = |bytes: Option<&[u8]>| -> Option<ModelMetadata> {
            bytes.and_then(|b| ModelMetadata::from_bytes(b).ok())
        };
        Ok(render_diff(path, parse(old).as_ref(), parse(new).as_ref()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::lfs::LfsStore;
    use crate::tensor::Tensor;
    use crate::theta::filter::{clean_checkpoint, ObjectAccess};
    use crate::util::tmp::TempDir;

    fn make_versions() -> (ModelMetadata, ModelMetadata) {
        let td = TempDir::new("diff").unwrap();
        let acc = ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        };
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![4, 4], vec![0.5; 16]).unwrap());
        ck.insert("b", Tensor::from_f32(vec![4], vec![0.1; 4]).unwrap());
        let v1 = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();

        let mut ck2 = Checkpoint::new();
        let mut w = vec![0.5f32; 16];
        w[3] = 9.0;
        ck2.insert("w", Tensor::from_f32(vec![4, 4], w).unwrap());
        ck2.insert("new_head", Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap());
        let v2 = clean_checkpoint(&acc, &ck2, "safetensors", Some(&v1), None, 1).unwrap();
        (v1, v2)
    }

    #[test]
    fn structured_diff() {
        let (v1, v2) = make_versions();
        let diff = ModelDiff::between(Some(&v1), Some(&v2));
        assert_eq!(diff.added, vec!["new_head"]);
        assert_eq!(diff.removed, vec!["b"]);
        assert_eq!(diff.modified, vec!["w"]);
        assert_eq!(diff.unchanged, 0);
    }

    #[test]
    fn identical_versions_empty_diff() {
        let (v1, _) = make_versions();
        let diff = ModelDiff::between(Some(&v1), Some(&v1));
        assert!(diff.is_empty());
        assert_eq!(diff.unchanged, 2);
    }

    #[test]
    fn rendered_diff_mentions_groups_and_types() {
        let (v1, v2) = make_versions();
        let text = render_diff("model.safetensors", Some(&v1), Some(&v2));
        assert!(text.contains("+ added    new_head"));
        assert!(text.contains("- removed  b"));
        assert!(text.contains("~ modified w"));
        assert!(text.contains("update="));
        assert!(text.contains("L2 distance"));
    }

    #[test]
    fn new_file_diff() {
        let (v1, _) = make_versions();
        let diff = ModelDiff::between(None, Some(&v1));
        assert_eq!(diff.added.len(), 2);
    }
}
