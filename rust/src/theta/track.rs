//! `git theta track` (paper §3.2 "Tracking a Model"): configure a
//! checkpoint path to use the theta filter/diff/merge drivers via the
//! attributes file.

use crate::gitcore::attributes::Attributes;
use crate::gitcore::repo::Repository;
use anyhow::Result;

/// Start tracking `pattern` (path or glob) with Git-Theta. Returns true
/// if a new attributes line was written.
pub fn track(repo: &Repository, pattern: &str) -> Result<bool> {
    let line = format!("{pattern} filter=theta diff=theta merge=theta");
    Attributes::add_line(repo.worktree(), &line)
}

/// Is this path currently tracked by Git-Theta?
pub fn is_tracked(repo: &Repository, path: &str) -> Result<bool> {
    Ok(repo.attributes()?.value_of(path, "filter").as_deref() == Some("theta"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn track_writes_attributes_once() {
        let td = TempDir::new("track").unwrap();
        let repo = Repository::init(td.path()).unwrap();
        assert!(!is_tracked(&repo, "model.safetensors").unwrap());
        assert!(track(&repo, "model.safetensors").unwrap());
        assert!(is_tracked(&repo, "model.safetensors").unwrap());
        // Idempotent.
        assert!(!track(&repo, "model.safetensors").unwrap());
        // Glob patterns work.
        assert!(track(&repo, "*.ckpt").unwrap());
        assert!(is_tracked(&repo, "sub/dir/m.ckpt").unwrap());
    }
}
