//! Extension merge strategies (paper §6 future work: "more
//! sophisticated Merge operations (Matena & Raffel, 2022)").
//!
//! * [`WeightedAverage`] — unequal-weight parameter averaging, weights
//!   from merge options (`--group '*'=weighted:0.7` style configs feed
//!   through [`set_branch_weights`]).
//! * [`FisherAverage`] — Fisher-weighted averaging (Matena & Raffel,
//!   2022): combine per-branch values weighted by a per-parameter
//!   importance estimate. Without access to each branch's data we use
//!   the magnitude-squared of each branch's *delta from the ancestor*
//!   as the importance proxy — parameters a branch actually moved are
//!   the ones its training considered important.
//!
//! Both strategies reconstruct through [`ConflictCtx::reconstruct`],
//! so chain prefixes shared with the other side (or with other groups)
//! hit the merge engine's per-invocation
//! [`ReconstructionCache`](crate::theta::checkout::ReconstructionCache)
//! (see `theta/merge.rs`) instead of being decoded again.

use crate::tensor::{fisher_average, Tensor};
use crate::theta::filter::store_payload;
use crate::theta::lsh::LshSignature;
use crate::theta::merge::{ConflictCtx, ConflictKind, MergeStrategy};
use crate::theta::updates::UpdatePayload;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU32, Ordering};

/// Global branch weights for [`WeightedAverage`] (ours, theirs), stored
/// as f32 bits. Defaults to (1, 1) = uniform.
static W_OURS: AtomicU32 = AtomicU32::new(0x3f80_0000);
static W_THEIRS: AtomicU32 = AtomicU32::new(0x3f80_0000);

/// Configure the branch weights used by the "weighted" strategy.
pub fn set_branch_weights(ours: f32, theirs: f32) {
    W_OURS.store(ours.to_bits(), Ordering::Relaxed);
    W_THEIRS.store(theirs.to_bits(), Ordering::Relaxed);
}

fn branch_weights() -> (f64, f64) {
    (
        f32::from_bits(W_OURS.load(Ordering::Relaxed)) as f64,
        f32::from_bits(W_THEIRS.load(Ordering::Relaxed)) as f64,
    )
}

fn store_dense(ctx: &ConflictCtx, values: Tensor) -> Result<crate::theta::metadata::GroupMetadata> {
    let sig = LshSignature::of_tensor(&values)?;
    let mut payload = UpdatePayload::new("dense");
    payload.tensors.insert("values".into(), values.clone());
    store_payload(ctx.access, &values, sig, payload, None)
}

/// `weighted`: w_a·ours + w_b·theirs, normalized.
pub struct WeightedAverage;

impl MergeStrategy for WeightedAverage {
    fn name(&self) -> &'static str {
        "weighted"
    }
    fn description(&self) -> &'static str {
        "weighted parameter average (weights set via set_branch_weights)"
    }
    fn applicable(&self, kind: ConflictKind) -> bool {
        kind != ConflictKind::DeleteModify
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<crate::theta::metadata::GroupMetadata>> {
        let ours = ctx.ours.context("weighted: missing our version")?;
        let theirs = ctx.theirs.context("weighted: missing their version")?;
        let a = ctx.reconstruct(ours)?;
        let b = ctx.reconstruct(theirs)?;
        if a.shape() != b.shape() {
            bail!("weighted: incompatible shapes for '{}'", ctx.group);
        }
        let (wa, wb) = branch_weights();
        let avg = crate::tensor::weighted_average(&[&a, &b], &[wa, wb])?;
        Ok(Some(store_dense(ctx, avg)?))
    }
}

/// `fisher`: per-parameter importance-weighted average, importance ≈
/// squared movement from the common ancestor (+ε so untouched
/// parameters average uniformly).
pub struct FisherAverage;

/// Importance floor: keeps the denominator nonzero and makes
/// parameters neither branch moved average uniformly.
const FISHER_EPS: f64 = 1e-12;

impl MergeStrategy for FisherAverage {
    fn name(&self) -> &'static str {
        "fisher"
    }
    fn description(&self) -> &'static str {
        "Fisher-style importance-weighted average (Matena & Raffel 2022; \
         importance = squared delta from ancestor)"
    }
    fn applicable(&self, kind: ConflictKind) -> bool {
        kind == ConflictKind::BothModified // needs ancestor + both sides
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<crate::theta::metadata::GroupMetadata>> {
        let ours = ctx.ours.context("fisher: missing our version")?;
        let theirs = ctx.theirs.context("fisher: missing their version")?;
        let anc = ctx.ancestor.context("fisher: missing ancestor")?;
        let a = ctx.reconstruct(ours)?;
        let b = ctx.reconstruct(theirs)?;
        let base = ctx.reconstruct(anc)?;
        if a.shape() != b.shape() || a.shape() != base.shape() {
            bail!("fisher: incompatible shapes for '{}'", ctx.group);
        }
        // Fused vectorized combine (tensor/ops.rs, next to
        // `weighted_average`): one pass, f64 accumulation, no
        // intermediate tensors — this runs once per conflicted group on
        // the merge hot path.
        let merged = fisher_average(&a, &b, &base, FISHER_EPS)?;
        Ok(Some(store_dense(ctx, merged)?))
    }
}

/// Register the extension strategies (called from `crate::init`).
pub fn register_extension_strategies() {
    crate::theta::merge::register_merge_strategy(Box::new(WeightedAverage));
    crate::theta::merge::register_merge_strategy(Box::new(FisherAverage));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::gitcore::drivers::MergeOptions;
    use crate::lfs::LfsStore;
    use crate::theta::filter::{clean_checkpoint, smudge_metadata, ObjectAccess};
    use crate::theta::merge::merge_metadata;
    use crate::util::tmp::TempDir;

    fn access(td: &TempDir) -> ObjectAccess {
        ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        }
    }

    fn ck(vals: Vec<f32>) -> Checkpoint {
        let mut c = Checkpoint::new();
        c.insert("w", Tensor::from_f32(vec![vals.len()], vals).unwrap());
        c
    }

    fn opts(strategy: &str) -> MergeOptions {
        MergeOptions {
            strategy: Some(strategy.to_string()),
            per_group: vec![],
            verbose: false,
        }
    }

    #[test]
    fn weighted_average_respects_weights() {
        crate::init();
        let td = TempDir::new("wavg").unwrap();
        let acc = access(&td);
        let base = ck(vec![0.0; 4]);
        let v0 = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let ours = clean_checkpoint(&acc, &ck(vec![1.0; 4]), "safetensors", Some(&v0), None, 1)
            .unwrap();
        let theirs = clean_checkpoint(&acc, &ck(vec![3.0; 4]), "safetensors", Some(&v0), None, 1)
            .unwrap();

        set_branch_weights(3.0, 1.0);
        let (m, _) = merge_metadata(&acc, Some(&v0), &ours, &theirs, &opts("weighted")).unwrap();
        let out = smudge_metadata(&acc, &m, 1).unwrap();
        // (3*1 + 1*3)/4 = 1.5
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), vec![1.5; 4]);
        set_branch_weights(1.0, 1.0);
    }

    #[test]
    fn fisher_average_prefers_the_branch_that_moved() {
        crate::init();
        let td = TempDir::new("fisher").unwrap();
        let acc = access(&td);
        let base = ck(vec![0.0, 0.0]);
        let v0 = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        // Ours moves elem 0 a lot; theirs moves elem 1 a lot; both also
        // nudge the other elem slightly.
        let ours = clean_checkpoint(&acc, &ck(vec![2.0, 0.1]), "safetensors", Some(&v0), None, 1)
            .unwrap();
        let theirs = clean_checkpoint(&acc, &ck(vec![0.1, 2.0]), "safetensors", Some(&v0), None, 1)
            .unwrap();
        let (m, resolved) =
            merge_metadata(&acc, Some(&v0), &ours, &theirs, &opts("fisher")).unwrap();
        assert_eq!(resolved.len(), 1);
        let out = smudge_metadata(&acc, &m, 1).unwrap();
        let w = out.get("w").unwrap().to_f32_vec().unwrap();
        // Each element lands near the branch that moved it hardest.
        assert!(w[0] > 1.8, "{w:?}");
        assert!(w[1] > 1.8, "{w:?}");
    }

    #[test]
    fn fisher_vectorized_matches_reference_loop() {
        crate::init();
        let td = TempDir::new("fisher-vec").unwrap();
        let acc = access(&td);
        let cv = vec![0.5f32, -0.25, 0.0, 1.0, 2.0];
        let av = vec![0.75f32, -0.25, 0.3, 1.0, -1.0];
        let bv = vec![0.5f32, 0.5, 0.1, 4.0, 2.5];
        let v0 = clean_checkpoint(&acc, &ck(cv.clone()), "safetensors", None, None, 1).unwrap();
        let ours = clean_checkpoint(&acc, &ck(av.clone()), "safetensors", Some(&v0), None, 1)
            .unwrap();
        let theirs = clean_checkpoint(&acc, &ck(bv.clone()), "safetensors", Some(&v0), None, 1)
            .unwrap();
        let (m, _) = merge_metadata(&acc, Some(&v0), &ours, &theirs, &opts("fisher")).unwrap();
        let out = smudge_metadata(&acc, &m, 1).unwrap();
        let got = out.get("w").unwrap().to_f32_vec().unwrap();
        // The element-wise reference this module used before moving to
        // the fused tensor op; the op must agree to f32 tolerance.
        for i in 0..cv.len() {
            let fa = (av[i] as f64 - cv[i] as f64).powi(2) + 1e-12;
            let fb = (bv[i] as f64 - cv[i] as f64).powi(2) + 1e-12;
            let want = ((fa * av[i] as f64 + fb * bv[i] as f64) / (fa + fb)) as f32;
            assert!(
                (got[i] - want).abs() <= 1e-5 * want.abs().max(1.0),
                "elem {i}: got {} want {want}",
                got[i]
            );
        }
    }

    #[test]
    fn fisher_requires_ancestor() {
        crate::init();
        use crate::theta::merge::menu_for;
        let names: Vec<&str> = menu_for(ConflictKind::BothAdded).iter().map(|s| s.name()).collect();
        assert!(!names.contains(&"fisher"));
        let names: Vec<&str> = menu_for(ConflictKind::BothModified)
            .iter()
            .map(|s| s.name())
            .collect();
        assert!(names.contains(&"fisher"));
        assert!(names.contains(&"weighted"));
    }
}
