//! The model metadata file — what Git actually versions for a tracked
//! checkpoint (paper §3.2 "Staging a Model").
//!
//! The clean filter replaces the multi-GB checkpoint with this small
//! text file: per parameter group it records the tensor's shape, dtype
//! and LSH signature, the update type, and the Git-LFS metadata of the
//! serialized update objects. Unchanged groups carry their previous
//! entry forward verbatim, so the JSON diff of two metadata versions is
//! exactly "which groups changed" — which is also what makes Git's own
//! text machinery efficient on it.
//!
//! Incremental updates (sparse/low-rank/IA3) must be applied on top of
//! a previous version of the group. The paper reconstructs that chain
//! by walking Git history at smudge time; here each incremental entry
//! **embeds its base entry** under `"prev"` (the same information the
//! history walk recovers, made explicit — see DESIGN.md §1). Chains
//! terminate at a dense entry, so metadata stays small: a chain only
//! grows while successive commits keep making incremental updates to
//! the same group, and resets on any dense update.

use crate::gitcore::object::Oid;
use crate::tensor::DType;
use crate::theta::lsh::{LshSignature, LshVerdict};
use crate::util::json::{Json, JsonObj};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Format marker in the metadata root.
pub const METADATA_MARKER: &str = "git-theta";
/// Metadata schema version this code reads and writes.
pub const METADATA_VERSION: u64 = 1;

/// Reference to one serialized object in the LFS store.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObjRef {
    /// sha256 of the serialized object.
    pub oid: Oid,
    /// Serialized size in bytes (what a transfer of it costs).
    pub size: u64,
}

impl ObjRef {
    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("oid", self.oid.to_hex());
        o.insert("size", self.size);
        Json::Obj(o)
    }

    fn from_json(j: &Json) -> Result<ObjRef> {
        Ok(ObjRef {
            oid: Oid::from_hex(j.get("oid").and_then(|v| v.as_str()).context("objref oid")?)?,
            size: j.get("size").and_then(|v| v.as_u64()).context("objref size")?,
        })
    }
}

/// Tensor-level metadata for a parameter group.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorInfo {
    /// Dimensions of the group's tensor.
    pub shape: Vec<usize>,
    /// Element dtype of the group's tensor.
    pub dtype: DType,
    /// LSH signature used for cheap change detection at clean time.
    pub lsh: LshSignature,
}

/// How a group was updated and where its serialized data lives.
#[derive(Debug, Clone, PartialEq)]
pub struct UpdateInfo {
    /// Update plug-in name: "dense", "sparse", "low_rank", "ia3", "trim".
    pub kind: String,
    /// Named LFS objects (e.g. {"data"} for dense, {"factors"} for LoRA).
    pub objects: BTreeMap<String, ObjRef>,
    /// Update-specific scalars (e.g. {"alpha": 2.0} or {"keep": 32000}).
    pub extra: Json,
}

/// Full metadata for one parameter group.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupMetadata {
    /// Shape/dtype/LSH of the group's current value.
    pub tensor: TensorInfo,
    /// How the group was updated and where its data lives.
    pub update: UpdateInfo,
    /// Base entry this (incremental) update applies on top of.
    pub prev: Option<Box<GroupMetadata>>,
}

impl GroupMetadata {
    /// Encode this entry (and its base chain) as JSON.
    pub fn to_json(&self) -> Json {
        let mut t = JsonObj::new();
        t.insert(
            "shape",
            Json::Arr(self.tensor.shape.iter().map(|&d| Json::from(d)).collect()),
        );
        t.insert("dtype", self.tensor.dtype.name());
        t.insert("lsh", self.tensor.lsh.to_json());

        let mut u = JsonObj::new();
        u.insert("type", self.update.kind.clone());
        let mut objs = JsonObj::new();
        for (k, v) in &self.update.objects {
            objs.insert(k.clone(), v.to_json());
        }
        u.insert("objects", objs);
        u.insert("extra", self.update.extra.clone());

        let mut g = JsonObj::new();
        g.insert("tensor", t);
        g.insert("update", u);
        g.insert(
            "prev",
            match &self.prev {
                Some(p) => p.to_json(),
                None => Json::Null,
            },
        );
        Json::Obj(g)
    }

    /// Decode an entry (recursively, including its base chain).
    pub fn from_json(j: &Json) -> Result<GroupMetadata> {
        let t = j.get("tensor").context("group missing tensor")?;
        let shape = t
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("tensor shape")?
            .iter()
            .map(|d| d.as_usize().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(t.get("dtype").and_then(|v| v.as_str()).context("dtype")?)
            .context("unknown dtype")?;
        let lsh = LshSignature::from_json(t.get("lsh").context("lsh")?)?;

        let u = j.get("update").context("group missing update")?;
        let kind = u
            .get("type")
            .and_then(|v| v.as_str())
            .context("update type")?
            .to_string();
        let mut objects = BTreeMap::new();
        if let Some(objs) = u.get("objects").and_then(|v| v.as_obj()) {
            for (k, v) in objs.iter() {
                objects.insert(k.clone(), ObjRef::from_json(v)?);
            }
        }
        let extra = u.get("extra").cloned().unwrap_or(Json::Null);

        let prev = match j.get("prev") {
            Some(Json::Null) | None => None,
            Some(p) => Some(Box::new(GroupMetadata::from_json(p)?)),
        };

        Ok(GroupMetadata {
            tensor: TensorInfo { shape, dtype, lsh },
            update: UpdateInfo {
                kind,
                objects,
                extra,
            },
            prev,
        })
    }

    /// All LFS oids referenced by this entry and its base chain.
    pub fn all_oids(&self, out: &mut Vec<Oid>) {
        for obj in self.update.objects.values() {
            out.push(obj.oid);
        }
        if let Some(p) = &self.prev {
            p.all_oids(out);
        }
    }

    /// Depth of the incremental chain (dense entry = 1).
    pub fn chain_depth(&self) -> usize {
        1 + self.prev.as_ref().map_or(0, |p| p.chain_depth())
    }

    /// Content hash identifying this entry's full chain: shape, dtype,
    /// LSH signature, update kind/extras, object oids, and the embedded
    /// base chain. Because reconstruction is a pure function of exactly
    /// this information, two entries with equal chain keys reconstruct
    /// to identical tensors — the property the checkout engine's
    /// memoized reconstruction cache relies on for its keying.
    pub fn chain_key(&self) -> Oid {
        Oid::of_bytes(self.to_json().to_string_compact().as_bytes())
    }

    /// Total serialized bytes referenced by this entry alone (not the chain).
    pub fn own_bytes(&self) -> u64 {
        self.update.objects.values().map(|o| o.size).sum()
    }

    /// The chain as a base-first list of `(chain_key, own oids)` pairs:
    /// element 0 is the dense anchor, the last element is this entry.
    /// This is the shape the wire negotiation advertises — a receiver
    /// holding every oid of a prefix of this list holds "depth k of
    /// chain X", and only the suffix entries (plus deltas against the
    /// deepest held entry) need to travel.
    pub fn chain_entries(&self) -> Vec<(Oid, Vec<Oid>)> {
        let mut out = match &self.prev {
            Some(p) => p.chain_entries(),
            None => Vec::new(),
        };
        out.push((
            self.chain_key(),
            self.update.objects.values().map(|o| o.oid).collect(),
        ));
        out
    }

    /// LSH proof that this entry and `other` hold the same tensor
    /// values (distance ≤ the paper's 1e-8 "unchanged" bound), however
    /// different their chains. The ambiguous `NeedsExactCheck` band
    /// counts as *not* matching, so this can under- but never
    /// over-claim equality — the merge engine's change-skipping and
    /// the diff driver's re-anchor classification both rely on that
    /// one-sidedness.
    pub fn values_match(&self, other: &GroupMetadata) -> bool {
        self.values_verdict(other) == ValueMatch::Equal
    }

    /// Tri-state LSH comparison of this entry's values against
    /// `other`'s: proven equal, proven different, or inside the
    /// ambiguous band where only an exact reconstruction + `allclose`
    /// can decide (paper: distances in [1e-8, 1e-6] are checked with
    /// `np.allclose`). Shape/dtype mismatches are definitively
    /// different. Callers that cannot afford the exact check treat
    /// [`ValueMatch::Ambiguous`] as different — the safe direction.
    pub fn values_verdict(&self, other: &GroupMetadata) -> ValueMatch {
        if self.tensor.shape != other.tensor.shape || self.tensor.dtype != other.tensor.dtype {
            return ValueMatch::Different;
        }
        match self.tensor.lsh.compare(&other.tensor.lsh) {
            LshVerdict::Unchanged => ValueMatch::Equal,
            LshVerdict::NeedsExactCheck => ValueMatch::Ambiguous,
            LshVerdict::Changed => ValueMatch::Different,
        }
    }
}

/// Outcome of [`GroupMetadata::values_verdict`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueMatch {
    /// LSH proves the values equal (distance ≤ 1e-8 bound).
    Equal,
    /// Distance estimate inside the ambiguous band: run the exact
    /// check ([`values_equal_exact`](crate::theta::checkout::values_equal_exact)).
    Ambiguous,
    /// Values (or shape/dtype) provably differ.
    Different,
}

/// The whole metadata file: one entry per parameter group.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMetadata {
    /// Checkpoint format plug-in that produced / will consume this model.
    pub format: String,
    /// Per-parameter-group entries, keyed by group name.
    pub groups: BTreeMap<String, GroupMetadata>,
}

impl ModelMetadata {
    /// Start an empty metadata file for a checkpoint format.
    pub fn new(format: impl Into<String>) -> ModelMetadata {
        ModelMetadata {
            format: format.into(),
            groups: BTreeMap::new(),
        }
    }

    /// Serialize to the pretty-printed JSON text Git versions.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut root = JsonObj::new();
        root.insert(METADATA_MARKER, METADATA_VERSION);
        root.insert("format", self.format.clone());
        let mut groups = JsonObj::new();
        for (name, g) in &self.groups {
            groups.insert(name.clone(), g.to_json());
        }
        root.insert("groups", groups);
        Json::Obj(root).to_string_pretty().into_bytes()
    }

    /// Parse a metadata file, rejecting non-metadata or versions this
    /// code does not understand.
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelMetadata> {
        let text = std::str::from_utf8(bytes).context("metadata is not utf-8")?;
        let json = Json::parse(text).context("metadata json")?;
        let version = json
            .get(METADATA_MARKER)
            .and_then(|v| v.as_u64())
            .context("not a git-theta metadata file")?;
        if version != METADATA_VERSION {
            bail!("unsupported metadata version {version}");
        }
        let format = json
            .get("format")
            .and_then(|v| v.as_str())
            .context("metadata missing format")?
            .to_string();
        let mut groups = BTreeMap::new();
        if let Some(gobj) = json.get("groups").and_then(|v| v.as_obj()) {
            for (name, g) in gobj.iter() {
                groups.insert(
                    name.clone(),
                    GroupMetadata::from_json(g)
                        .with_context(|| format!("group '{name}'"))?,
                );
            }
        }
        Ok(ModelMetadata { format, groups })
    }

    /// Cheap sniffer used by hooks scanning commits for model files.
    pub fn is_metadata(bytes: &[u8]) -> bool {
        let head = &bytes[..bytes.len().min(64)];
        match std::str::from_utf8(head) {
            Ok(s) => s.trim_start().starts_with('{') && s.contains(METADATA_MARKER),
            Err(_) => false,
        }
    }

    /// All LFS oids referenced by every group (including base chains).
    pub fn all_oids(&self) -> Vec<Oid> {
        let mut out = Vec::new();
        for g in self.groups.values() {
            g.all_oids(&mut out);
        }
        out.sort();
        out.dedup();
        out
    }

    /// Oids referenced by `self` but not by `prev_version` — i.e. the
    /// objects written by the commit that introduced this metadata.
    pub fn new_oids_vs(&self, prev_version: Option<&ModelMetadata>) -> Vec<Oid> {
        let prev: std::collections::HashSet<Oid> = prev_version
            .map(|m| m.all_oids().into_iter().collect())
            .unwrap_or_default();
        self.all_oids()
            .into_iter()
            .filter(|o| !prev.contains(o))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theta::lsh::LshSignature;

    fn sample_group(seed: &[f32], kind: &str, prev: Option<GroupMetadata>) -> GroupMetadata {
        GroupMetadata {
            tensor: TensorInfo {
                shape: vec![seed.len()],
                dtype: DType::F32,
                lsh: LshSignature::of_values(seed),
            },
            update: UpdateInfo {
                kind: kind.to_string(),
                objects: [(
                    "data".to_string(),
                    ObjRef {
                        oid: Oid::of_bytes(kind.as_bytes()),
                        size: 42,
                    },
                )]
                .into_iter()
                .collect(),
                extra: Json::Null,
            },
            prev: prev.map(Box::new),
        }
    }

    #[test]
    fn roundtrip_with_chain() {
        let base = sample_group(&[1.0, 2.0], "dense", None);
        let inc = sample_group(&[1.5, 2.5], "sparse", Some(base));
        let mut meta = ModelMetadata::new("safetensors");
        meta.groups.insert("layer0/w".into(), inc);
        meta.groups.insert("layer0/b".into(), sample_group(&[0.0], "dense", None));

        let bytes = meta.to_bytes();
        assert!(ModelMetadata::is_metadata(&bytes));
        let back = ModelMetadata::from_bytes(&bytes).unwrap();
        assert_eq!(back, meta);
        assert_eq!(back.groups["layer0/w"].chain_depth(), 2);
    }

    #[test]
    fn all_oids_and_new_oids() {
        let base = sample_group(&[1.0], "dense", None);
        let inc = sample_group(&[2.0], "sparse", Some(base.clone()));
        let mut v1 = ModelMetadata::new("safetensors");
        v1.groups.insert("w".into(), base);
        let mut v2 = ModelMetadata::new("safetensors");
        v2.groups.insert("w".into(), inc);

        assert_eq!(v1.all_oids().len(), 1);
        assert_eq!(v2.all_oids().len(), 2); // sparse + embedded dense
        let new = v2.new_oids_vs(Some(&v1));
        assert_eq!(new, vec![Oid::of_bytes(b"sparse")]);
        assert_eq!(v2.new_oids_vs(None).len(), 2);
    }

    #[test]
    fn chain_key_distinguishes_chains() {
        let base = sample_group(&[1.0], "dense", None);
        let other = sample_group(&[2.0], "dense", None);
        let inc = sample_group(&[2.0], "sparse", Some(base.clone()));
        // Equal content -> equal key; any difference in the entry or its
        // embedded chain -> different key.
        assert_eq!(base.chain_key(), base.clone().chain_key());
        assert_ne!(base.chain_key(), other.chain_key());
        assert_ne!(inc.chain_key(), base.chain_key());
        let inc_other = sample_group(&[2.0], "sparse", Some(other));
        assert_ne!(inc.chain_key(), inc_other.chain_key());
        // Roundtripping through JSON preserves the key.
        let back = GroupMetadata::from_json(&inc.to_json()).unwrap();
        assert_eq!(back.chain_key(), inc.chain_key());
    }

    #[test]
    fn chain_entries_list_base_first() {
        let base = sample_group(&[1.0], "dense", None);
        let mid = sample_group(&[2.0], "sparse", Some(base.clone()));
        let tip = sample_group(&[3.0], "ia3", Some(mid.clone()));
        let entries = tip.chain_entries();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].0, base.chain_key());
        assert_eq!(entries[1].0, mid.chain_key());
        assert_eq!(entries[2].0, tip.chain_key());
        assert_eq!(entries[0].1, vec![Oid::of_bytes(b"dense")]);
        assert_eq!(entries[2].1, vec![Oid::of_bytes(b"ia3")]);
    }

    #[test]
    fn values_match_ignores_chain_shape() {
        // Same values behind different chains (a re-anchor) match;
        // different values never do.
        let a = sample_group(&[1.0, 2.0], "dense", None);
        let b = sample_group(&[1.0, 2.0], "sparse", Some(a.clone()));
        assert_ne!(a, b);
        assert!(a.values_match(&b));
        let c = sample_group(&[9.0, 2.0], "dense", None);
        assert!(!a.values_match(&c));
        let mut d = sample_group(&[1.0, 2.0], "dense", None);
        d.tensor.shape = vec![2, 1];
        assert!(!a.values_match(&d));
    }

    #[test]
    fn rejects_non_metadata() {
        assert!(!ModelMetadata::is_metadata(b"version https://git-lfs"));
        assert!(ModelMetadata::from_bytes(b"{}").is_err());
        assert!(ModelMetadata::from_bytes(b"\x00\x01binary").is_err());
    }
}
