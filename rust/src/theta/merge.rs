//! The Git-Theta merge driver and Merge strategy plug-ins (paper §3.2
//! "Merging Models From Different Branches", §3.3 "Merges").
//!
//! When two branches modify the same model, the driver three-ways the
//! metadata files: groups equal on both sides (or changed on only one)
//! merge automatically; truly conflicting groups are resolved by a
//! [`MergeStrategy`] plug-in. Strategies advertise which conflict kinds
//! they can resolve, so the interactive menu only offers applicable
//! ones. Built-ins mirror the paper: take ours ("us"), take theirs
//! ("them"), keep the common ancestor, or **average the parameters**
//! (Wortsman et al. 2022; Choshen et al. 2022b).

use crate::gitcore::drivers::{MergeDriver, MergeOptions, MergeOutcome};
use crate::gitcore::repo::Repository;
use crate::tensor::weighted_average;
use crate::theta::filter::{reconstruct_group, store_payload, ObjectAccess};
use crate::theta::lsh::LshSignature;
use crate::theta::metadata::{GroupMetadata, ModelMetadata};
use crate::theta::updates::UpdatePayload;
use crate::util::glob::Glob;
use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;
use std::collections::BTreeSet;
use std::sync::RwLock;

/// What kind of conflict a group is in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConflictKind {
    /// Changed on both branches relative to the ancestor.
    BothModified,
    /// Added on both branches with different values.
    BothAdded,
    /// Deleted on one branch, modified on the other.
    DeleteModify,
}

/// Everything a strategy needs to resolve one group.
pub struct ConflictCtx<'a> {
    pub group: &'a str,
    pub kind: ConflictKind,
    pub ancestor: Option<&'a GroupMetadata>,
    pub ours: Option<&'a GroupMetadata>,
    pub theirs: Option<&'a GroupMetadata>,
    pub access: &'a ObjectAccess,
}

/// A merge-strategy plug-in.
pub trait MergeStrategy: Send + Sync {
    /// Keyword used to select the strategy (paper: "the keyword used to
    /// select its strategy").
    fn name(&self) -> &'static str;

    /// One-line summary shown in the merge menu.
    fn description(&self) -> &'static str;

    /// Which conflict kinds this strategy can resolve.
    fn applicable(&self, kind: ConflictKind) -> bool;

    /// Resolve: `Ok(Some(entry))` keeps the group with that metadata,
    /// `Ok(None)` removes the group from the merged model.
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>>;
}

struct TakeUs;
impl MergeStrategy for TakeUs {
    fn name(&self) -> &'static str {
        "us"
    }
    fn description(&self) -> &'static str {
        "keep the change from the current branch"
    }
    fn applicable(&self, _k: ConflictKind) -> bool {
        true
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        Ok(ctx.ours.cloned())
    }
}

struct TakeThem;
impl MergeStrategy for TakeThem {
    fn name(&self) -> &'static str {
        "them"
    }
    fn description(&self) -> &'static str {
        "take the change from the other branch"
    }
    fn applicable(&self, _k: ConflictKind) -> bool {
        true
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        Ok(ctx.theirs.cloned())
    }
}

struct TakeAncestor;
impl MergeStrategy for TakeAncestor {
    fn name(&self) -> &'static str {
        "ancestor"
    }
    fn description(&self) -> &'static str {
        "discard both changes and keep the common ancestor"
    }
    fn applicable(&self, kind: ConflictKind) -> bool {
        kind != ConflictKind::BothAdded // no ancestor exists in that case
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        Ok(ctx.ancestor.cloned())
    }
}

struct Average;
impl MergeStrategy for Average {
    fn name(&self) -> &'static str {
        "average"
    }
    fn description(&self) -> &'static str {
        "average the parameters from both branches (Wortsman et al. 2022)"
    }
    fn applicable(&self, kind: ConflictKind) -> bool {
        kind != ConflictKind::DeleteModify // needs both sides present
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        let ours = ctx.ours.context("average: missing our version")?;
        let theirs = ctx.theirs.context("average: missing their version")?;
        let a = reconstruct_group(ctx.access, ours)?;
        let b = reconstruct_group(ctx.access, theirs)?;
        if a.shape() != b.shape() {
            bail!(
                "average: group '{}' has incompatible shapes {:?} vs {:?}",
                ctx.group,
                a.shape(),
                b.shape()
            );
        }
        let avg = weighted_average(&[&a, &b], &[1.0, 1.0])?;
        let sig = LshSignature::of_tensor(&avg)?;
        // The merged value is a fresh dense version (it matches neither
        // parent, so it terminates both chains).
        let mut payload = UpdatePayload::new("dense");
        payload.tensors.insert("values".into(), avg.clone());
        Ok(Some(store_payload(ctx.access, &avg, sig, payload, None)?))
    }
}

static STRATEGIES: Lazy<RwLock<Vec<&'static dyn MergeStrategy>>> = Lazy::new(|| {
    RwLock::new(vec![
        &Average as &'static dyn MergeStrategy,
        &TakeUs,
        &TakeThem,
        &TakeAncestor,
    ])
});

/// Register a user merge-strategy plug-in.
pub fn register_merge_strategy(s: Box<dyn MergeStrategy>) {
    STRATEGIES.write().unwrap().push(Box::leak(s));
}

/// Look up a strategy by keyword.
pub fn merge_strategy(name: &str) -> Option<&'static dyn MergeStrategy> {
    STRATEGIES.read().unwrap().iter().copied().find(|s| s.name() == name)
}

/// The strategies applicable to a conflict kind (drives the menu; paper:
/// "allowing the driver to build a menu with only relevant plug-ins").
pub fn menu_for(kind: ConflictKind) -> Vec<&'static dyn MergeStrategy> {
    STRATEGIES
        .read()
        .unwrap()
        .iter()
        .copied()
        .filter(|s| s.applicable(kind))
        .collect()
}

/// Render the merge menu for a conflicted group.
pub fn render_menu(group: &str, kind: ConflictKind) -> String {
    let mut out = format!("conflict in parameter group '{group}' ({kind:?}); options:\n");
    for s in menu_for(kind) {
        out.push_str(&format!("  [{}] {}\n", s.name(), s.description()));
    }
    out
}

/// Pick a strategy for a group from merge options.
fn select_strategy(
    group: &str,
    kind: ConflictKind,
    opts: &MergeOptions,
) -> Result<&'static dyn MergeStrategy> {
    // Per-group overrides first (paper future work: "easy-to-use
    // per-parameter configuration").
    for (pattern, name) in &opts.per_group {
        if Glob::new(pattern).matches(group) {
            let s = merge_strategy(name)
                .with_context(|| format!("unknown merge strategy '{name}'"))?;
            if !s.applicable(kind) {
                bail!(
                    "strategy '{name}' cannot resolve {kind:?} conflicts (group '{group}')"
                );
            }
            return Ok(s);
        }
    }
    if let Some(name) = &opts.strategy {
        let s = merge_strategy(name).with_context(|| format!("unknown merge strategy '{name}'"))?;
        if !s.applicable(kind) {
            bail!("strategy '{name}' cannot resolve {kind:?} conflicts (group '{group}')");
        }
        return Ok(s);
    }
    bail!(
        "{}\nre-run with --strategy <name> (or --group <glob>=<name>)",
        render_menu(group, kind)
    );
}

/// Merge three metadata versions group-by-group.
pub fn merge_metadata(
    access: &ObjectAccess,
    ancestor: Option<&ModelMetadata>,
    ours: &ModelMetadata,
    theirs: &ModelMetadata,
    opts: &MergeOptions,
) -> Result<(ModelMetadata, Vec<String>)> {
    let empty = ModelMetadata::new(ours.format.clone());
    let anc = ancestor.unwrap_or(&empty);
    let mut names: BTreeSet<&String> = BTreeSet::new();
    names.extend(anc.groups.keys());
    names.extend(ours.groups.keys());
    names.extend(theirs.groups.keys());

    let mut merged = ModelMetadata::new(ours.format.clone());
    let mut resolved = Vec::new();
    for name in names {
        let o = anc.groups.get(name);
        let a = ours.groups.get(name);
        let b = theirs.groups.get(name);
        // Equal on both sides (including both-deleted) merges trivially;
        // "Git-Theta can ignore parameter groups that are equivalent
        // across histories".
        let pick: Option<GroupMetadata> = if a == b {
            a.cloned()
        } else if a == o {
            b.cloned()
        } else if b == o {
            a.cloned()
        } else {
            let kind = match (o, a, b) {
                (None, Some(_), Some(_)) => ConflictKind::BothAdded,
                (Some(_), None, Some(_)) | (Some(_), Some(_), None) => ConflictKind::DeleteModify,
                _ => ConflictKind::BothModified,
            };
            let strategy = select_strategy(name, kind, opts)?;
            resolved.push(format!("{name} ({})", strategy.name()));
            strategy.resolve(&ConflictCtx {
                group: name,
                kind,
                ancestor: o,
                ours: a,
                theirs: b,
                access,
            })?
        };
        if let Some(entry) = pick {
            merged.groups.insert(name.clone(), entry);
        }
    }
    Ok((merged, resolved))
}

/// The `merge=theta` driver.
pub struct ThetaMerge;

impl MergeDriver for ThetaMerge {
    fn merge(
        &self,
        repo: &Repository,
        path: &str,
        ancestor: Option<&[u8]>,
        ours: Option<&[u8]>,
        theirs: Option<&[u8]>,
        opts: &MergeOptions,
    ) -> Result<MergeOutcome> {
        let parse = |bytes: Option<&[u8]>| -> Result<Option<ModelMetadata>> {
            bytes.map(ModelMetadata::from_bytes).transpose()
        };
        let anc = parse(ancestor)?;
        let ours = match parse(ours)? {
            Some(m) => m,
            None => {
                return Ok(MergeOutcome::Conflict(format!(
                    "'{path}' deleted on our branch but modified on theirs; \
                     use a whole-file resolution"
                )))
            }
        };
        let theirs = match parse(theirs)? {
            Some(m) => m,
            None => {
                return Ok(MergeOutcome::Conflict(format!(
                    "'{path}' deleted on their branch but modified on ours"
                )))
            }
        };
        let access = ObjectAccess::for_repo(repo)?;
        match merge_metadata(&access, anc.as_ref(), &ours, &theirs, opts) {
            Ok((merged, _resolved)) => Ok(MergeOutcome::Resolved(merged.to_bytes())),
            Err(e) => Ok(MergeOutcome::Conflict(format!("{e:#}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::lfs::LfsStore;
    use crate::tensor::Tensor;
    use crate::theta::filter::{clean_checkpoint, smudge_metadata};
    use crate::util::tmp::TempDir;

    fn access(td: &TempDir) -> ObjectAccess {
        ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        }
    }

    fn ck_with(w: Vec<f32>, b: Vec<f32>) -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![2, 2], w).unwrap());
        ck.insert("b", Tensor::from_f32(vec![2], b).unwrap());
        ck
    }

    fn opts(strategy: &str) -> MergeOptions {
        MergeOptions {
            strategy: Some(strategy.to_string()),
            per_group: vec![],
        }
    }

    #[test]
    fn non_overlapping_changes_merge_without_strategy() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![1., 2., 3., 4.], vec![0., 0.]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();

        let ours_ck = ck_with(vec![9., 2., 3., 4.], vec![0., 0.]); // change w
        let theirs_ck = ck_with(vec![1., 2., 3., 4.], vec![5., 5.]); // change b
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        let (merged, resolved) =
            merge_metadata(&acc, Some(&v_base), &ours, &theirs, &MergeOptions::default()).unwrap();
        assert!(resolved.is_empty());
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), vec![9., 2., 3., 4.]);
        assert_eq!(out.get("b").unwrap().to_f32_vec().unwrap(), vec![5., 5.]);
    }

    #[test]
    fn overlapping_changes_need_strategy() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![0., 0., 0., 0.], vec![0., 0.]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let ours_ck = ck_with(vec![2., 2., 2., 2.], vec![0., 0.]);
        let theirs_ck = ck_with(vec![4., 4., 4., 4.], vec![0., 0.]);
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        // No strategy -> error listing the menu.
        let err = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &MergeOptions::default())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("average"), "{msg}");
        assert!(msg.contains("[us]"), "{msg}");

        // Average resolves to the elementwise mean.
        let (merged, resolved) =
            merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("average")).unwrap();
        assert_eq!(resolved.len(), 1);
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), vec![3., 3., 3., 3.]);

        // us / them / ancestor.
        for (name, expect) in [("us", 2.0f32), ("them", 4.0), ("ancestor", 0.0)] {
            let (m, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts(name)).unwrap();
            let out = smudge_metadata(&acc, &m, 1).unwrap();
            assert_eq!(
                out.get("w").unwrap().to_f32_vec().unwrap(),
                vec![expect; 4],
                "strategy {name}"
            );
        }
    }

    #[test]
    fn per_group_overrides_beat_global_strategy() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![0.; 4], vec![0.; 2]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let ours_ck = ck_with(vec![2.; 4], vec![2.; 2]);
        let theirs_ck = ck_with(vec![4.; 4], vec![4.; 2]);
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        let opts = MergeOptions {
            strategy: Some("average".into()),
            per_group: vec![("b".into(), "them".into())],
        };
        let (merged, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts).unwrap();
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), vec![3.; 4]);
        assert_eq!(out.get("b").unwrap().to_f32_vec().unwrap(), vec![4.; 2]);
    }

    #[test]
    fn menu_filters_by_conflict_kind() {
        let both_added: Vec<&str> = menu_for(ConflictKind::BothAdded)
            .iter()
            .map(|s| s.name())
            .collect();
        assert!(both_added.contains(&"us"));
        assert!(!both_added.contains(&"ancestor")); // no ancestor exists
        let del_mod: Vec<&str> = menu_for(ConflictKind::DeleteModify)
            .iter()
            .map(|s| s.name())
            .collect();
        assert!(!del_mod.contains(&"average"));
        assert!(del_mod.contains(&"ancestor"));
    }

    #[test]
    fn delete_modify_conflict() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![1.; 4], vec![1.; 2]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();

        // Ours deletes "b"; theirs modifies it.
        let mut ours_ck = base.clone();
        ours_ck.remove("b");
        let theirs_ck = ck_with(vec![1.; 4], vec![7.; 2]);
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        // "them" keeps their modified version.
        let (m, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("them")).unwrap();
        assert!(m.groups.contains_key("b"));
        // "us" removes the group.
        let (m, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("us")).unwrap();
        assert!(!m.groups.contains_key("b"));
        // "average" is not applicable.
        assert!(merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("average")).is_err());
    }

    #[test]
    fn average_of_incremental_updates_resolves_chains() {
        // LoRA on one branch, sparse on the other; average must
        // reconstruct both chains before combining.
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![1., 1., 1., 1.], vec![0.; 2]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let ours_ck = ck_with(vec![1., 5., 1., 1.], vec![0.; 2]); // sparse
        let theirs_ck = ck_with(vec![3., 1., 1., 3.], vec![0.; 2]); // sparse too
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        assert_eq!(ours.groups["w"].update.kind, "sparse");

        let (merged, _) =
            merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("average")).unwrap();
        assert_eq!(merged.groups["w"].update.kind, "dense");
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(
            out.get("w").unwrap().to_f32_vec().unwrap(),
            vec![2., 3., 1., 2.]
        );
    }
}
