//! The Git-Theta merge driver and Merge strategy plug-ins (paper §3.2
//! "Merging Models From Different Branches", §3.3 "Merges").
//!
//! When two branches modify the same model, the driver three-ways the
//! metadata files: groups equal on both sides (or changed on only one)
//! merge automatically; truly conflicting groups are resolved by a
//! [`MergeStrategy`] plug-in. Strategies advertise which conflict kinds
//! they can resolve, so the interactive menu only offers applicable
//! ones. Built-ins mirror the paper: take ours ("us"), take theirs
//! ("them"), keep the common ancestor, or **average the parameters**
//! (Wortsman et al. 2022; Choshen et al. 2022b).
//!
//! Resolution runs on the **group-parallel merge engine**
//! ([`merge_metadata_opts`]), which layers four independent levers on
//! top of the per-group strategy calls (each toggleable via
//! [`EngineOptions`], measured by `bench merge`):
//!
//! * **Shared reconstruction cache** — one
//!   [`ReconstructionCache`] per invocation, keyed by
//!   [`GroupMetadata::chain_key`], shared by every strategy on every
//!   worker. The ancestor/ours/theirs chains of one conflict share
//!   their ancestor prefix, so the prefix is decoded once instead of
//!   once per side.
//! * **Batched prefetch** — every LFS object any conflicted group's
//!   three sides reference is collected up front and fetched as a
//!   single negotiation + pack, instead of a lazy download per missing
//!   object mid-resolution.
//! * **Parallel resolution** — independent conflicted groups resolve
//!   concurrently on [`par`] workers; output assembly follows input
//!   (name) order, so the merged metadata is deterministic regardless
//!   of thread count.
//! * **Change-skipping** — a conflict whose LSH signatures prove one
//!   side value-unchanged (e.g. a `git-theta snapshot` re-anchor that
//!   rewrote metadata but not values) is resolved without any
//!   reconstruction, so merge cost scales with the *changed* parameter
//!   set rather than model size.

use crate::gitcore::drivers::{MergeDriver, MergeOptions, MergeOutcome};
use crate::gitcore::object::Oid;
use crate::gitcore::repo::Repository;
use crate::tensor::{weighted_average, Tensor};
use crate::theta::checkout::{self, ReconstructionCache};
use crate::theta::filter::{store_payload, ObjectAccess};
use crate::theta::lsh::LshSignature;
use crate::theta::metadata::{GroupMetadata, ModelMetadata};
use crate::theta::updates::UpdatePayload;
use crate::util::glob::Glob;
use crate::util::par;
use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;
use std::collections::BTreeSet;
use std::sync::RwLock;

/// What kind of conflict a group is in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConflictKind {
    /// Changed on both branches relative to the ancestor.
    BothModified,
    /// Added on both branches with different values.
    BothAdded,
    /// Deleted on one branch, modified on the other.
    DeleteModify,
}

/// Everything a strategy needs to resolve one group.
pub struct ConflictCtx<'a> {
    /// Name of the conflicted parameter group.
    pub group: &'a str,
    /// How the group conflicts.
    pub kind: ConflictKind,
    /// The group's entry at the merge base (None for [`ConflictKind::BothAdded`]).
    pub ancestor: Option<&'a GroupMetadata>,
    /// The group's entry on our branch (None when we deleted it).
    pub ours: Option<&'a GroupMetadata>,
    /// The group's entry on their branch (None when they deleted it).
    pub theirs: Option<&'a GroupMetadata>,
    /// LFS access for reconstructing chains and storing resolutions.
    pub access: &'a ObjectAccess,
    /// The engine's shared per-invocation reconstruction cache (None
    /// when the cache lever is off). Strategies reconstruct through
    /// [`ConflictCtx::reconstruct`] so chain prefixes shared between
    /// sides — or with other groups on other workers — decode once.
    pub cache: Option<&'a ReconstructionCache>,
}

impl ConflictCtx<'_> {
    /// Reconstruct a chain's full tensor through the engine's shared
    /// [`ReconstructionCache`] (plain uncached resolution when the
    /// engine runs without one).
    pub fn reconstruct(&self, entry: &GroupMetadata) -> Result<Tensor> {
        checkout::reconstruct(self.access, entry, self.cache)
    }
}

/// A merge-strategy plug-in.
pub trait MergeStrategy: Send + Sync {
    /// Keyword used to select the strategy (paper: "the keyword used to
    /// select its strategy").
    fn name(&self) -> &'static str;

    /// One-line summary shown in the merge menu.
    fn description(&self) -> &'static str;

    /// Which conflict kinds this strategy can resolve.
    fn applicable(&self, kind: ConflictKind) -> bool;

    /// Resolve: `Ok(Some(entry))` keeps the group with that metadata,
    /// `Ok(None)` removes the group from the merged model.
    ///
    /// Called from the engine's worker threads: implementations must
    /// not rely on process-global mutable state beyond what their
    /// `Send + Sync` bound already promises.
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>>;
}

struct TakeUs;
impl MergeStrategy for TakeUs {
    fn name(&self) -> &'static str {
        "us"
    }
    fn description(&self) -> &'static str {
        "keep the change from the current branch"
    }
    fn applicable(&self, _k: ConflictKind) -> bool {
        true
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        Ok(ctx.ours.cloned())
    }
}

struct TakeThem;
impl MergeStrategy for TakeThem {
    fn name(&self) -> &'static str {
        "them"
    }
    fn description(&self) -> &'static str {
        "take the change from the other branch"
    }
    fn applicable(&self, _k: ConflictKind) -> bool {
        true
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        Ok(ctx.theirs.cloned())
    }
}

struct TakeAncestor;
impl MergeStrategy for TakeAncestor {
    fn name(&self) -> &'static str {
        "ancestor"
    }
    fn description(&self) -> &'static str {
        "discard both changes and keep the common ancestor"
    }
    fn applicable(&self, kind: ConflictKind) -> bool {
        kind != ConflictKind::BothAdded // no ancestor exists in that case
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        Ok(ctx.ancestor.cloned())
    }
}

struct Average;
impl MergeStrategy for Average {
    fn name(&self) -> &'static str {
        "average"
    }
    fn description(&self) -> &'static str {
        "average the parameters from both branches (Wortsman et al. 2022)"
    }
    fn applicable(&self, kind: ConflictKind) -> bool {
        kind != ConflictKind::DeleteModify // needs both sides present
    }
    fn resolve(&self, ctx: &ConflictCtx) -> Result<Option<GroupMetadata>> {
        let ours = ctx.ours.context("average: missing our version")?;
        let theirs = ctx.theirs.context("average: missing their version")?;
        let a = ctx.reconstruct(ours)?;
        let b = ctx.reconstruct(theirs)?;
        if a.shape() != b.shape() {
            bail!(
                "average: group '{}' has incompatible shapes {:?} vs {:?}",
                ctx.group,
                a.shape(),
                b.shape()
            );
        }
        let avg = weighted_average(&[&a, &b], &[1.0, 1.0])?;
        let sig = LshSignature::of_tensor(&avg)?;
        // The merged value is a fresh dense version (it matches neither
        // parent, so it terminates both chains).
        let mut payload = UpdatePayload::new("dense");
        payload.tensors.insert("values".into(), avg.clone());
        Ok(Some(store_payload(ctx.access, &avg, sig, payload, None)?))
    }
}

static STRATEGIES: Lazy<RwLock<Vec<&'static dyn MergeStrategy>>> = Lazy::new(|| {
    RwLock::new(vec![
        &Average as &'static dyn MergeStrategy,
        &TakeUs,
        &TakeThem,
        &TakeAncestor,
    ])
});

/// Register a user merge-strategy plug-in.
pub fn register_merge_strategy(s: Box<dyn MergeStrategy>) {
    STRATEGIES.write().unwrap().push(Box::leak(s));
}

/// Look up a strategy by keyword.
pub fn merge_strategy(name: &str) -> Option<&'static dyn MergeStrategy> {
    STRATEGIES.read().unwrap().iter().copied().find(|s| s.name() == name)
}

/// The strategies applicable to a conflict kind (drives the menu; paper:
/// "allowing the driver to build a menu with only relevant plug-ins").
pub fn menu_for(kind: ConflictKind) -> Vec<&'static dyn MergeStrategy> {
    STRATEGIES
        .read()
        .unwrap()
        .iter()
        .copied()
        .filter(|s| s.applicable(kind))
        .collect()
}

/// Render the merge menu for a conflicted group.
pub fn render_menu(group: &str, kind: ConflictKind) -> String {
    let mut out = format!("conflict in parameter group '{group}' ({kind:?}); options:\n");
    for s in menu_for(kind) {
        out.push_str(&format!("  [{}] {}\n", s.name(), s.description()));
    }
    out
}

/// Pick a strategy for a group from merge options.
fn select_strategy(
    group: &str,
    kind: ConflictKind,
    opts: &MergeOptions,
) -> Result<&'static dyn MergeStrategy> {
    // Per-group overrides first (paper future work: "easy-to-use
    // per-parameter configuration").
    for (pattern, name) in &opts.per_group {
        if Glob::new(pattern).matches(group) {
            let s = merge_strategy(name)
                .with_context(|| format!("unknown merge strategy '{name}'"))?;
            if !s.applicable(kind) {
                bail!(
                    "strategy '{name}' cannot resolve {kind:?} conflicts (group '{group}')"
                );
            }
            return Ok(s);
        }
    }
    if let Some(name) = &opts.strategy {
        let s = merge_strategy(name).with_context(|| format!("unknown merge strategy '{name}'"))?;
        if !s.applicable(kind) {
            bail!("strategy '{name}' cannot resolve {kind:?} conflicts (group '{group}')");
        }
        return Ok(s);
    }
    bail!(
        "{}\nre-run with --strategy <name> (or --group <glob>=<name>)",
        render_menu(group, kind)
    );
}

/// The merge engine's tuning levers. Defaults enable everything; the
/// `bench merge` ablation toggles each independently against the
/// serial baseline (`EngineOptions::serial`).
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Worker threads for parallel conflict resolution (1 = serial).
    pub threads: usize,
    /// Share one [`ReconstructionCache`] across every resolution of the
    /// invocation, so chain prefixes common to ancestor/ours/theirs —
    /// or to several groups — decode once.
    pub cache: bool,
    /// Collect every missing LFS object across all three sides of every
    /// conflict up front and fetch them as one negotiation + pack,
    /// instead of a lazy per-object download mid-resolution.
    pub prefetch: bool,
    /// Auto-resolve conflicts whose LSH signatures prove one side
    /// value-unchanged (no reconstruction, no strategy call) — the way
    /// Git auto-merges identical hunks regardless of `-X`. A per-group
    /// `--group <glob>=<strategy>` override always wins over skipping.
    /// See [`merge_metadata_opts`] for the exact picking rules.
    pub value_skip: bool,
}

impl Default for EngineOptions {
    fn default() -> EngineOptions {
        EngineOptions {
            threads: par::default_threads(),
            cache: true,
            prefetch: true,
            value_skip: true,
        }
    }
}

impl EngineOptions {
    /// The all-levers-off serial baseline (the pre-engine behavior;
    /// the benchmark ablation's reference row).
    pub fn serial() -> EngineOptions {
        EngineOptions {
            threads: 1,
            cache: false,
            prefetch: false,
            value_skip: false,
        }
    }
}

/// Per-invocation statistics of the merge engine, surfaced by
/// `git-theta merge --verbose` and asserted on by tests/benchmarks.
#[derive(Debug, Clone, Default)]
pub struct MergeStats {
    /// Parameter groups examined (union of all three sides).
    pub groups: usize,
    /// Groups merged by metadata equality (equal on both sides, or
    /// changed on only one) — never reconstructed.
    pub trivial: usize,
    /// Conflicts auto-resolved by LSH value-equality — never
    /// reconstructed (the change-skipping lever).
    pub value_skipped: usize,
    /// LSH comparisons that landed in the ambiguous `NeedsExactCheck`
    /// band and were settled by reconstructing both sides and running
    /// `allclose` (the exact-check fallback; each may have enabled a
    /// skip that conservative classification would have resolved).
    pub exact_checks: u64,
    /// Conflicted groups resolved by a strategy, as "name (strategy)"
    /// in deterministic (name) order.
    pub resolved: Vec<String>,
    /// Reconstruction-cache lookups answered from the cache.
    pub cache_hits: u64,
    /// Reconstruction-cache lookups that had to reconstruct.
    pub cache_misses: u64,
    /// LFS objects missing locally that the up-front batched prefetch
    /// requested (0 when nothing was missing or the lever is off).
    pub prefetched: usize,
}

impl MergeStats {
    /// One-line `--verbose` summary for a merged file.
    pub fn render_verbose(&self, path: &str) -> String {
        format!(
            "merge '{path}': {} group(s) — {} trivial, {} value-skipped ({} exact check(s)), \
             {} resolved; cache {} hit(s) / {} miss(es); {} object(s) prefetched",
            self.groups,
            self.trivial,
            self.value_skipped,
            self.exact_checks,
            self.resolved.len(),
            self.cache_hits,
            self.cache_misses,
            self.prefetched
        )
    }
}

/// True when both entries exist and their values are provably equal:
/// either the LSH signatures prove it outright
/// ([`GroupMetadata::values_verdict`] → `Equal`), or the estimate
/// lands in the ambiguous `NeedsExactCheck` band and the **exact
/// fallback** — reconstruct both sides through the engine's shared
/// cache, compare with `allclose` — settles it (paper: "weights that
/// have a Euclidean distance ∈ [1e-8, 1e-6] are checked with
/// np.allclose"). Skipping is therefore never less safe than
/// resolving, and near-identical re-anchors no longer force a
/// strategy.
///
/// Exact checks run during serial classification, *before* the batched
/// prefetch, so a remote-backed store fetches their chain objects
/// lazily — acceptable because the ambiguous band is rare by
/// construction (LSH calibration puts ≥99% of unchanged groups in
/// `Equal`); batching ambiguous pairs into their own prefetch is the
/// follow-up if real workloads disagree.
fn values_unchanged(
    access: &ObjectAccess,
    cache: Option<&ReconstructionCache>,
    exact_checks: &mut u64,
    x: Option<&GroupMetadata>,
    y: Option<&GroupMetadata>,
) -> Result<bool> {
    let (x, y) = match (x, y) {
        (Some(x), Some(y)) => (x, y),
        _ => return Ok(false),
    };
    Ok(match x.values_verdict(y) {
        crate::theta::metadata::ValueMatch::Equal => true,
        crate::theta::metadata::ValueMatch::Different => false,
        crate::theta::metadata::ValueMatch::Ambiguous => {
            *exact_checks += 1;
            checkout::values_equal_exact(access, x, y, cache)?
        }
    })
}

/// A classified conflict awaiting (parallel) resolution.
struct Conflict<'a> {
    name: &'a String,
    kind: ConflictKind,
    ancestor: Option<&'a GroupMetadata>,
    ours: Option<&'a GroupMetadata>,
    theirs: Option<&'a GroupMetadata>,
    strategy: &'static dyn MergeStrategy,
}

/// Merge three metadata versions group-by-group on the parallel merge
/// engine.
///
/// Phases (each lever independently toggleable via [`EngineOptions`]):
///
/// 1. **Classify** (serial, metadata-only except the rare ambiguous
///    band, which falls back to an exact reconstruct + `allclose`
///    through the shared cache). Groups equal on both sides,
///    or changed on only one, merge trivially. Remaining conflicts
///    whose LSH signatures prove one side value-unchanged are resolved
///    by picking the other side — ours-vs-theirs value-equal keeps
///    ours, ours-vs-ancestor value-equal takes theirs (ours carried no
///    value change), theirs-vs-ancestor value-equal keeps ours. Groups
///    matched by a per-group strategy override are never skipped (the
///    targeted directive wins). Strategy selection for true conflicts
///    also happens here so the interactive menu error is deterministic
///    (first conflicted group in name order).
/// 2. **Prefetch**. All LFS objects referenced by any side of any
///    remaining conflict are fetched as a single pack.
/// 3. **Resolve**. Conflicts resolve concurrently, sharing one
///    [`ReconstructionCache`]; results are assembled in name order, so
///    output is independent of scheduling.
pub fn merge_metadata_opts(
    access: &ObjectAccess,
    ancestor: Option<&ModelMetadata>,
    ours: &ModelMetadata,
    theirs: &ModelMetadata,
    opts: &MergeOptions,
    engine: &EngineOptions,
) -> Result<(ModelMetadata, MergeStats)> {
    let empty = ModelMetadata::new(ours.format.clone());
    let anc = ancestor.unwrap_or(&empty);
    let mut names: BTreeSet<&String> = BTreeSet::new();
    names.extend(anc.groups.keys());
    names.extend(ours.groups.keys());
    names.extend(theirs.groups.keys());

    let mut merged = ModelMetadata::new(ours.format.clone());
    let mut stats = MergeStats {
        groups: names.len(),
        ..Default::default()
    };

    // The shared cache is created before classification: the exact
    // fallback for ambiguous LSH bands reconstructs through it, and
    // any prefix it resolves is reused by phase-3 strategies.
    let cache = if engine.cache {
        Some(ReconstructionCache::new())
    } else {
        None
    };

    // Phase 1: classification. `Some(pick)` keeps (or, for None-pick,
    // drops) the group without reconstruction (except for rare
    // ambiguous-band exact checks); unresolved conflicts accumulate
    // for the parallel phase.
    let mut conflicts: Vec<Conflict> = Vec::new();
    for name in names {
        let o = anc.groups.get(name);
        let a = ours.groups.get(name);
        let b = theirs.groups.get(name);
        // Equal on both sides (including both-deleted) merges trivially;
        // "Git-Theta can ignore parameter groups that are equivalent
        // across histories".
        let trivial: Option<Option<&GroupMetadata>> = if a == b {
            Some(a)
        } else if a == o {
            Some(b)
        } else if b == o {
            Some(a)
        } else {
            None
        };
        if let Some(pick) = trivial {
            stats.trivial += 1;
            if let Some(entry) = pick {
                merged.groups.insert(name.clone(), entry.clone());
            }
            continue;
        }
        // Change-skipping treats value-equality like Git treats
        // identical hunks: not a conflict at all, so the global
        // `--strategy` (which, like Git's `-X`, only governs real
        // conflicts) does not suppress it. A per-group override is a
        // targeted directive about exactly this group, though — it
        // always wins over skipping.
        let per_group_override = opts
            .per_group
            .iter()
            .any(|(pattern, _)| Glob::new(pattern).matches(name));
        if engine.value_skip && !per_group_override {
            // Metadata differs on both sides, but the LSH signatures —
            // with the exact allclose fallback for ambiguous bands —
            // may still prove one side value-unchanged (e.g. a snapshot
            // re-anchor, or a bitwise-drifted but numerically identical
            // rewrite). Prefer keeping our entry when both sides are
            // value-equal.
            let c = cache.as_ref();
            let x = &mut stats.exact_checks;
            let pick: Option<Option<&GroupMetadata>> = if values_unchanged(access, c, x, a, b)? {
                Some(a)
            } else if values_unchanged(access, c, x, a, o)? {
                Some(b)
            } else if values_unchanged(access, c, x, b, o)? {
                Some(a)
            } else {
                None
            };
            if let Some(pick) = pick {
                stats.value_skipped += 1;
                if let Some(entry) = pick {
                    merged.groups.insert(name.clone(), entry.clone());
                }
                continue;
            }
        }
        let kind = match (o, a, b) {
            (None, Some(_), Some(_)) => ConflictKind::BothAdded,
            (Some(_), None, Some(_)) | (Some(_), Some(_), None) => ConflictKind::DeleteModify,
            _ => ConflictKind::BothModified,
        };
        let strategy = select_strategy(name, kind, opts)?;
        conflicts.push(Conflict {
            name,
            kind,
            ancestor: o,
            ours: a,
            theirs: b,
            strategy,
        });
    }

    // Phase 2: one negotiation + one pack for everything any conflict
    // might reconstruct, instead of a lazy download per missing object.
    if engine.prefetch && !conflicts.is_empty() {
        let mut oids: Vec<Oid> = Vec::new();
        for c in &conflicts {
            for entry in [c.ancestor, c.ours, c.theirs].into_iter().flatten() {
                entry.all_oids(&mut oids);
            }
        }
        oids.sort();
        oids.dedup();
        stats.prefetched = oids.iter().filter(|o| !access.store.contains(o)).count();
        access.prefetch(&oids)?;
    }

    // Phase 3: parallel resolution with the shared cache; assembly in
    // input (name) order keeps the output deterministic.
    let entries = par::try_par_map(&conflicts, engine.threads, |_, c| {
        c.strategy
            .resolve(&ConflictCtx {
                group: c.name,
                kind: c.kind,
                ancestor: c.ancestor,
                ours: c.ours,
                theirs: c.theirs,
                access,
                cache: cache.as_ref(),
            })
            .with_context(|| format!("resolving parameter group '{}'", c.name))
    })?;
    for (c, entry) in conflicts.iter().zip(entries) {
        stats.resolved.push(format!("{} ({})", c.name, c.strategy.name()));
        if let Some(e) = entry {
            merged.groups.insert(c.name.clone(), e);
        }
    }
    if let Some(cache) = &cache {
        stats.cache_hits = cache.hits();
        stats.cache_misses = cache.misses();
    }
    Ok((merged, stats))
}

/// Merge three metadata versions group-by-group with the default engine
/// (all levers on). Returns the merged metadata and the "name
/// (strategy)" list of driver-resolved groups.
pub fn merge_metadata(
    access: &ObjectAccess,
    ancestor: Option<&ModelMetadata>,
    ours: &ModelMetadata,
    theirs: &ModelMetadata,
    opts: &MergeOptions,
) -> Result<(ModelMetadata, Vec<String>)> {
    let (merged, stats) =
        merge_metadata_opts(access, ancestor, ours, theirs, opts, &EngineOptions::default())?;
    Ok((merged, stats.resolved))
}

/// The `merge=theta` driver.
pub struct ThetaMerge;

impl MergeDriver for ThetaMerge {
    fn merge(
        &self,
        repo: &Repository,
        path: &str,
        ancestor: Option<&[u8]>,
        ours: Option<&[u8]>,
        theirs: Option<&[u8]>,
        opts: &MergeOptions,
    ) -> Result<MergeOutcome> {
        let parse = |bytes: Option<&[u8]>| -> Result<Option<ModelMetadata>> {
            bytes.map(ModelMetadata::from_bytes).transpose()
        };
        let anc = parse(ancestor)?;
        let ours = match parse(ours)? {
            Some(m) => m,
            None => {
                return Ok(MergeOutcome::Conflict(format!(
                    "'{path}' deleted on our branch but modified on theirs; \
                     use a whole-file resolution"
                )))
            }
        };
        let theirs = match parse(theirs)? {
            Some(m) => m,
            None => {
                return Ok(MergeOutcome::Conflict(format!(
                    "'{path}' deleted on their branch but modified on ours"
                )))
            }
        };
        let access = ObjectAccess::for_repo(repo)?;
        let engine = EngineOptions::default();
        match merge_metadata_opts(&access, anc.as_ref(), &ours, &theirs, opts, &engine) {
            Ok((merged, stats)) => {
                if opts.verbose {
                    eprintln!("{}", stats.render_verbose(path));
                }
                Ok(MergeOutcome::Resolved(merged.to_bytes()))
            }
            Err(e) => Ok(MergeOutcome::Conflict(format!("{e:#}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::lfs::LfsStore;
    use crate::tensor::Tensor;
    use crate::theta::filter::{clean_checkpoint, smudge_metadata};
    use crate::util::tmp::TempDir;

    fn access(td: &TempDir) -> ObjectAccess {
        ObjectAccess {
            store: LfsStore::open(td.path()),
            remote: None,
        }
    }

    fn ck_with(w: Vec<f32>, b: Vec<f32>) -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![2, 2], w).unwrap());
        ck.insert("b", Tensor::from_f32(vec![2], b).unwrap());
        ck
    }

    fn opts(strategy: &str) -> MergeOptions {
        MergeOptions {
            strategy: Some(strategy.to_string()),
            per_group: vec![],
            verbose: false,
        }
    }

    #[test]
    fn non_overlapping_changes_merge_without_strategy() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![1., 2., 3., 4.], vec![0., 0.]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();

        let ours_ck = ck_with(vec![9., 2., 3., 4.], vec![0., 0.]); // change w
        let theirs_ck = ck_with(vec![1., 2., 3., 4.], vec![5., 5.]); // change b
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        let (merged, resolved) =
            merge_metadata(&acc, Some(&v_base), &ours, &theirs, &MergeOptions::default()).unwrap();
        assert!(resolved.is_empty());
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), vec![9., 2., 3., 4.]);
        assert_eq!(out.get("b").unwrap().to_f32_vec().unwrap(), vec![5., 5.]);
    }

    #[test]
    fn overlapping_changes_need_strategy() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![0., 0., 0., 0.], vec![0., 0.]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let ours_ck = ck_with(vec![2., 2., 2., 2.], vec![0., 0.]);
        let theirs_ck = ck_with(vec![4., 4., 4., 4.], vec![0., 0.]);
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        // No strategy -> error listing the menu.
        let err = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &MergeOptions::default())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("average"), "{msg}");
        assert!(msg.contains("[us]"), "{msg}");

        // Average resolves to the elementwise mean.
        let (merged, resolved) =
            merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("average")).unwrap();
        assert_eq!(resolved.len(), 1);
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), vec![3., 3., 3., 3.]);

        // us / them / ancestor.
        for (name, expect) in [("us", 2.0f32), ("them", 4.0), ("ancestor", 0.0)] {
            let (m, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts(name)).unwrap();
            let out = smudge_metadata(&acc, &m, 1).unwrap();
            assert_eq!(
                out.get("w").unwrap().to_f32_vec().unwrap(),
                vec![expect; 4],
                "strategy {name}"
            );
        }
    }

    #[test]
    fn per_group_overrides_beat_global_strategy() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![0.; 4], vec![0.; 2]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let ours_ck = ck_with(vec![2.; 4], vec![2.; 2]);
        let theirs_ck = ck_with(vec![4.; 4], vec![4.; 2]);
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        let opts = MergeOptions {
            strategy: Some("average".into()),
            per_group: vec![("b".into(), "them".into())],
            verbose: false,
        };
        let (merged, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts).unwrap();
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), vec![3.; 4]);
        assert_eq!(out.get("b").unwrap().to_f32_vec().unwrap(), vec![4.; 2]);
    }

    #[test]
    fn menu_filters_by_conflict_kind() {
        let both_added: Vec<&str> = menu_for(ConflictKind::BothAdded)
            .iter()
            .map(|s| s.name())
            .collect();
        assert!(both_added.contains(&"us"));
        assert!(!both_added.contains(&"ancestor")); // no ancestor exists
        let del_mod: Vec<&str> = menu_for(ConflictKind::DeleteModify)
            .iter()
            .map(|s| s.name())
            .collect();
        assert!(!del_mod.contains(&"average"));
        assert!(del_mod.contains(&"ancestor"));
    }

    #[test]
    fn delete_modify_conflict() {
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![1.; 4], vec![1.; 2]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();

        // Ours deletes "b"; theirs modifies it.
        let mut ours_ck = base.clone();
        ours_ck.remove("b");
        let theirs_ck = ck_with(vec![1.; 4], vec![7.; 2]);
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        // "them" keeps their modified version.
        let (m, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("them")).unwrap();
        assert!(m.groups.contains_key("b"));
        // "us" removes the group.
        let (m, _) = merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("us")).unwrap();
        assert!(!m.groups.contains_key("b"));
        // "average" is not applicable.
        assert!(merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("average")).is_err());
    }

    #[test]
    fn average_of_incremental_updates_resolves_chains() {
        // LoRA on one branch, sparse on the other; average must
        // reconstruct both chains before combining.
        let td = TempDir::new("merge").unwrap();
        let acc = access(&td);
        let base = ck_with(vec![1., 1., 1., 1.], vec![0.; 2]);
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let ours_ck = ck_with(vec![1., 5., 1., 1.], vec![0.; 2]); // sparse
        let theirs_ck = ck_with(vec![3., 1., 1., 3.], vec![0.; 2]); // sparse too
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        assert_eq!(ours.groups["w"].update.kind, "sparse");

        let (merged, _) =
            merge_metadata(&acc, Some(&v_base), &ours, &theirs, &opts("average")).unwrap();
        assert_eq!(merged.groups["w"].update.kind, "dense");
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(
            out.get("w").unwrap().to_f32_vec().unwrap(),
            vec![2., 3., 1., 2.]
        );
    }

    #[test]
    fn parallel_cached_engine_matches_serial_byte_for_byte() {
        let td = TempDir::new("merge-par").unwrap();
        let acc = access(&td);
        // Several groups in conflict at once, so the parallel phase has
        // real fan-out.
        let mut base = Checkpoint::new();
        for g in 0..6 {
            base.insert(
                format!("g{g}"),
                Tensor::from_f32(vec![16], vec![g as f32; 16]).unwrap(),
            );
        }
        let v_base = clean_checkpoint(&acc, &base, "safetensors", None, None, 1).unwrap();
        let mut ours_ck = base.clone();
        let mut theirs_ck = base.clone();
        for g in 0..6 {
            ours_ck.insert(
                format!("g{g}"),
                Tensor::from_f32(vec![16], vec![g as f32 + 1.0; 16]).unwrap(),
            );
            theirs_ck.insert(
                format!("g{g}"),
                Tensor::from_f32(vec![16], vec![g as f32 + 3.0; 16]).unwrap(),
            );
        }
        let ours = clean_checkpoint(&acc, &ours_ck, "safetensors", Some(&v_base), None, 1).unwrap();
        let theirs =
            clean_checkpoint(&acc, &theirs_ck, "safetensors", Some(&v_base), None, 1).unwrap();

        let (serial, s_stats) = merge_metadata_opts(
            &acc,
            Some(&v_base),
            &ours,
            &theirs,
            &opts("average"),
            &EngineOptions::serial(),
        )
        .unwrap();
        let (full, f_stats) = merge_metadata_opts(
            &acc,
            Some(&v_base),
            &ours,
            &theirs,
            &opts("average"),
            &EngineOptions {
                threads: 4,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(serial.to_bytes(), full.to_bytes());
        assert_eq!(s_stats.resolved, f_stats.resolved);
        assert_eq!(f_stats.resolved.len(), 6);
        // Serial baseline reports no cache traffic at all.
        assert_eq!((s_stats.cache_hits, s_stats.cache_misses), (0, 0));
    }

    #[test]
    fn shared_cache_hits_across_merge_sides() {
        let td = TempDir::new("merge-cache").unwrap();
        let acc = access(&td);
        // Build a deep shared chain, then diverge both sides from it:
        // the common prefix must be decoded once, not once per side.
        let mut ck = ck_with(vec![0.; 4], vec![0.; 2]);
        let mut meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();
        let deep_opts = crate::theta::filter::CleanOptions {
            snapshot_depth: None,
            threads: 1,
            ..Default::default()
        };
        for i in 0..4 {
            let mut vals = ck.get("w").unwrap().to_f32_vec().unwrap();
            vals[i % 4] += 1.0;
            ck.insert("w", Tensor::from_f32(vec![2, 2], vals).unwrap());
            meta = crate::theta::filter::clean_checkpoint_opts(
                &acc,
                &ck,
                "safetensors",
                Some(&meta),
                &deep_opts,
            )
            .unwrap();
        }
        assert!(meta.groups["w"].chain_depth() >= 4);
        let mut ours_ck = ck.clone();
        let mut theirs_ck = ck.clone();
        let mut ov = ck.get("w").unwrap().to_f32_vec().unwrap();
        ov[0] += 5.0;
        ours_ck.insert("w", Tensor::from_f32(vec![2, 2], ov).unwrap());
        let mut tv = ck.get("w").unwrap().to_f32_vec().unwrap();
        tv[3] += 7.0;
        theirs_ck.insert("w", Tensor::from_f32(vec![2, 2], tv).unwrap());
        let ours = crate::theta::filter::clean_checkpoint_opts(
            &acc,
            &ours_ck,
            "safetensors",
            Some(&meta),
            &deep_opts,
        )
        .unwrap();
        let theirs = crate::theta::filter::clean_checkpoint_opts(
            &acc,
            &theirs_ck,
            "safetensors",
            Some(&meta),
            &deep_opts,
        )
        .unwrap();

        let (_, stats) = merge_metadata_opts(
            &acc,
            Some(&meta),
            &ours,
            &theirs,
            &opts("average"),
            &EngineOptions::default(),
        )
        .unwrap();
        assert!(
            stats.cache_hits >= 1,
            "expected the shared ancestor prefix to hit the cache: {stats:?}"
        );
    }

    #[test]
    fn value_equal_conflicts_skip_strategy_resolution() {
        let td = TempDir::new("merge-skip").unwrap();
        let acc = access(&td);
        // Grow a chain so a snapshot re-anchor has something to rewrite.
        let mut ck = ck_with(vec![0.; 4], vec![0.; 2]);
        let mut meta = clean_checkpoint(&acc, &ck, "safetensors", None, None, 1).unwrap();
        let deep_opts = crate::theta::filter::CleanOptions {
            snapshot_depth: None,
            threads: 1,
            ..Default::default()
        };
        for i in 0..3 {
            let mut vals = ck.get("w").unwrap().to_f32_vec().unwrap();
            vals[i] += 1.0;
            ck.insert("w", Tensor::from_f32(vec![2, 2], vals).unwrap());
            meta = crate::theta::filter::clean_checkpoint_opts(
                &acc,
                &ck,
                "safetensors",
                Some(&meta),
                &deep_opts,
            )
            .unwrap();
        }
        // Ours: re-anchor only (metadata changes, values do not).
        let (ours, report) = crate::theta::checkout::snapshot_metadata(&acc, &meta, 1).unwrap();
        assert!(report.reanchored >= 1);
        assert_ne!(ours.groups["w"], meta.groups["w"]);
        // Theirs: a real value change.
        let mut theirs_ck = ck.clone();
        let mut tv = ck.get("w").unwrap().to_f32_vec().unwrap();
        tv[3] = 9.0;
        theirs_ck.insert("w", Tensor::from_f32(vec![2, 2], tv.clone()).unwrap());
        let theirs = crate::theta::filter::clean_checkpoint_opts(
            &acc,
            &theirs_ck,
            "safetensors",
            Some(&meta),
            &deep_opts,
        )
        .unwrap();

        // With change-skipping: no strategy needed, theirs' change wins.
        let (merged, stats) = merge_metadata_opts(
            &acc,
            Some(&meta),
            &ours,
            &theirs,
            &MergeOptions::default(),
            &EngineOptions::default(),
        )
        .unwrap();
        assert_eq!(stats.value_skipped, 1);
        assert!(stats.resolved.is_empty());
        assert_eq!(merged.groups["w"], theirs.groups["w"]);
        let out = smudge_metadata(&acc, &merged, 1).unwrap();
        assert_eq!(out.get("w").unwrap().to_f32_vec().unwrap(), tv);

        // With the lever off the same merge demands a strategy.
        let err = merge_metadata_opts(
            &acc,
            Some(&meta),
            &ours,
            &theirs,
            &MergeOptions::default(),
            &EngineOptions {
                value_skip: false,
                ..EngineOptions::default()
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("conflict in parameter group 'w'"));

        // A targeted per-group override always beats change-skipping:
        // "us" keeps our re-anchored entry even though theirs carries
        // the only value change.
        let per_group = MergeOptions {
            strategy: None,
            per_group: vec![("w".into(), "us".into())],
            verbose: false,
        };
        let (merged, stats) = merge_metadata_opts(
            &acc,
            Some(&meta),
            &ours,
            &theirs,
            &per_group,
            &EngineOptions::default(),
        )
        .unwrap();
        assert_eq!(stats.value_skipped, 0);
        assert_eq!(stats.resolved, vec!["w (us)".to_string()]);
        assert_eq!(merged.groups["w"], ours.groups["w"]);
    }

    #[test]
    fn ambiguous_band_falls_back_to_exact_check_and_skips() {
        use crate::theta::lsh::{LshSignature, LshVerdict};
        use crate::theta::metadata::ValueMatch;
        use crate::theta::updates::UpdatePayload;
        use crate::util::rng::Pcg64;

        // Find a deterministic pair of value vectors whose LSH
        // comparison lands in the ambiguous NeedsExactCheck band
        // (distance ~3e-8, inside [1e-8, 1e-6]) — the estimate has
        // sampling spread, so probe seeds until one lands.
        let n = 4096usize;
        let (base, near) = (0..200u64)
            .find_map(|seed| {
                let mut rng = Pcg64::new(1000 + seed);
                let base: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 2e-3).collect();
                let per = 3e-8f32 / (n as f32).sqrt();
                let near: Vec<f32> = base.iter().map(|v| v + per).collect();
                let a = LshSignature::of_values(&base);
                let b = LshSignature::of_values(&near);
                (a.compare(&b) == LshVerdict::NeedsExactCheck).then(|| (base, near))
            })
            .expect("no ambiguous pair in 200 deterministic seeds");

        let td = TempDir::new("merge-exact").unwrap();
        let acc = access(&td);
        let dense = |vals: &[f32]| -> GroupMetadata {
            let t = Tensor::from_f32(vec![vals.len()], vals.to_vec()).unwrap();
            let sig = LshSignature::of_tensor(&t).unwrap();
            let mut payload = UpdatePayload::new("dense");
            payload.tensors.insert("values".into(), t.clone());
            store_payload(&acc, &t, sig, payload, None).unwrap()
        };
        let e_base = dense(&base);
        let e_near = dense(&near); // ours: numerically identical rewrite
        let mut changed = base.clone();
        changed[0] += 0.5;
        let e_changed = dense(&changed); // theirs: a real value change
        assert_eq!(e_base.values_verdict(&e_near), ValueMatch::Ambiguous);

        let mk = |e: &GroupMetadata| {
            let mut m = ModelMetadata::new("safetensors");
            m.groups.insert("w".to_string(), e.clone());
            m
        };
        let (anc, ours, theirs) = (mk(&e_base), mk(&e_near), mk(&e_changed));

        // Exact fallback proves ours value-unchanged vs the ancestor →
        // theirs' change wins with no strategy and no conflict.
        let (merged, stats) = merge_metadata_opts(
            &acc,
            Some(&anc),
            &ours,
            &theirs,
            &MergeOptions::default(),
            &EngineOptions::default(),
        )
        .unwrap();
        assert_eq!(stats.value_skipped, 1, "{stats:?}");
        assert!(stats.exact_checks >= 1, "{stats:?}");
        assert!(stats.resolved.is_empty());
        assert_eq!(merged.groups["w"], theirs.groups["w"]);

        // Parity: byte-identical to an explicit "them" resolution.
        let (explicit, _) = merge_metadata_opts(
            &acc,
            Some(&anc),
            &ours,
            &theirs,
            &opts("them"),
            &EngineOptions {
                value_skip: false,
                ..EngineOptions::default()
            },
        )
        .unwrap();
        assert_eq!(merged.to_bytes(), explicit.to_bytes());

        // With skipping off the same merge demands a strategy — the
        // fallback is what rescued it.
        let err = merge_metadata_opts(
            &acc,
            Some(&anc),
            &ours,
            &theirs,
            &MergeOptions::default(),
            &EngineOptions {
                value_skip: false,
                ..EngineOptions::default()
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("conflict in parameter group 'w'"), "{err:#}");
    }

    #[test]
    fn verbose_stats_render_mentions_counters() {
        let s = MergeStats {
            groups: 5,
            trivial: 2,
            value_skipped: 1,
            exact_checks: 1,
            resolved: vec!["w (average)".into()],
            cache_hits: 3,
            cache_misses: 7,
            prefetched: 4,
        };
        let line = s.render_verbose("model.safetensors");
        for needle in [
            "5 group(s)",
            "2 trivial",
            "1 value-skipped",
            "1 exact check(s)",
            "3 hit",
            "7 miss",
        ] {
            assert!(line.contains(needle), "{line}");
        }
        assert!(line.contains("4 object(s) prefetched"), "{line}");
    }
}
