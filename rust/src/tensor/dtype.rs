//! Element dtypes and half-precision conversions.

use std::fmt;

/// Element type of a tensor, matching the set of dtypes that appear in
/// the checkpoint formats Git-Theta supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F64,
    F32,
    BF16,
    F16,
    I64,
    I32,
    U8,
    Bool,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::F64 | DType::I64 => 8,
            DType::F32 | DType::I32 => 4,
            DType::BF16 | DType::F16 => 2,
            DType::U8 | DType::Bool => 1,
        }
    }

    /// Canonical lowercase name used in metadata files.
    pub fn name(self) -> &'static str {
        match self {
            DType::F64 => "f64",
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::I64 => "i64",
            DType::I32 => "i32",
            DType::U8 => "u8",
            DType::Bool => "bool",
        }
    }

    /// Parse from a metadata name. Accepts both our canonical names and
    /// the safetensors spellings ("F32", "BF16", ...).
    pub fn parse(s: &str) -> Option<DType> {
        Some(match s.to_ascii_lowercase().as_str() {
            "f64" | "float64" => DType::F64,
            "f32" | "float32" => DType::F32,
            "bf16" | "bfloat16" => DType::BF16,
            "f16" | "float16" => DType::F16,
            "i64" | "int64" => DType::I64,
            "i32" | "int32" => DType::I32,
            "u8" | "uint8" => DType::U8,
            "bool" => DType::Bool,
            _ => return None,
        })
    }

    /// The safetensors header spelling.
    pub fn safetensors_name(self) -> &'static str {
        match self {
            DType::F64 => "F64",
            DType::F32 => "F32",
            DType::BF16 => "BF16",
            DType::F16 => "F16",
            DType::I64 => "I64",
            DType::I32 => "I32",
            DType::U8 => "U8",
            DType::Bool => "BOOL",
        }
    }

    pub fn is_float(self) -> bool {
        matches!(self, DType::F64 | DType::F32 | DType::BF16 | DType::F16)
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// bfloat16 → f32 (bf16 is the top 16 bits of an f32).
#[inline]
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// f32 → bfloat16 with round-to-nearest-even.
#[inline]
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        // Preserve NaN, force a quiet bit so truncation can't make it Inf.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
    let _ = round_bit;
    (rounded >> 16) as u16
}

/// IEEE half → f32.
#[inline]
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits >> 15) & 1) as u32;
    let exp = ((bits >> 10) & 0x1f) as u32;
    let frac = (bits & 0x3ff) as u32;
    let f32_bits = if exp == 0 {
        if frac == 0 {
            sign << 31 // signed zero
        } else {
            // Subnormal: normalize.
            let mut e = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 31) | ((e as u32) << 23) | ((f & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        (sign << 31) | (0xff << 23) | (frac << 13) // Inf / NaN
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(f32_bits)
}

/// f32 → IEEE half with round-to-nearest-even.
#[inline]
pub fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 31) & 1) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;

    if exp == 0xff {
        // Inf / NaN
        let nan = if frac != 0 {
            0x200 | (frac >> 13) as u16 & 0x3ff | 1
        } else {
            0
        };
        return (sign << 15) | (0x1f << 10) | nan;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return (sign << 15) | (0x1f << 10); // overflow → Inf
    }
    if unbiased >= -14 {
        // Normal range.
        let half_exp = (unbiased + 15) as u32;
        let mut half_frac = frac >> 13;
        // Round to nearest even on the dropped 13 bits.
        let rem = frac & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (half_frac & 1) == 1) {
            half_frac += 1;
        }
        let out = (half_exp << 10) + half_frac; // carry may bump exponent
        return (sign << 15) | out as u16;
    }
    if unbiased >= -24 {
        // Subnormal half: frac_h = round(mantissa * 2^(unbiased + 1) / 2^-23)
        // i.e. a right shift by (-1 - unbiased) with round-to-nearest-even.
        let shift = (-1 - unbiased) as u32;
        let mantissa = frac | 0x80_0000;
        let mut half_frac = mantissa >> shift;
        let rem = mantissa & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && (half_frac & 1) == 1) {
            half_frac += 1;
        }
        return (sign << 15) | half_frac as u16;
    }
    sign << 15 // underflow → signed zero
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes_and_names_roundtrip() {
        for dt in [
            DType::F64,
            DType::F32,
            DType::BF16,
            DType::F16,
            DType::I64,
            DType::I32,
            DType::U8,
            DType::Bool,
        ] {
            assert_eq!(DType::parse(dt.name()), Some(dt));
            assert_eq!(DType::parse(dt.safetensors_name()), Some(dt));
            assert!(dt.size() > 0);
        }
        assert_eq!(DType::parse("complex64"), None);
    }

    #[test]
    fn bf16_roundtrip_exact_for_bf16_values() {
        // Values representable in bf16 survive f32 -> bf16 -> f32.
        for v in [0.0f32, 1.0, -2.5, 0.15625, 3.0e38, -1.0e-30] {
            let b = f32_to_bf16(v);
            let back = bf16_to_f32(b);
            assert_eq!(f32_to_bf16(back), b);
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-9 is halfway between bf16(1.0) and the next bf16.
        let v = f32::from_bits(0x3f80_8000);
        let b = f32_to_bf16(v);
        // Ties to even: mantissa of 1.0 is even, so round down to 1.0.
        assert_eq!(bf16_to_f32(b), 1.0);
        // Slightly above the tie rounds up.
        let v2 = f32::from_bits(0x3f80_8001);
        assert!(bf16_to_f32(f32_to_bf16(v2)) > 1.0);
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0xc000), -2.0);
        assert_eq!(f16_to_f32(0x7bff), 65504.0); // max half
        assert_eq!(f16_to_f32(0x0001), 5.960464477539063e-8); // min subnormal
        assert!(f16_to_f32(0x7c00).is_infinite());
        assert!(f16_to_f32(0x7e00).is_nan());
    }

    #[test]
    fn f16_roundtrip_bits() {
        // Every finite half value round-trips bit-exactly through f32.
        for bits in 0u16..=0xffff {
            let f = f16_to_f32(bits);
            if f.is_nan() {
                assert!(f16_to_f32(f32_to_f16(f)).is_nan());
            } else {
                assert_eq!(f32_to_f16(f), bits, "bits {bits:#06x} -> {f}");
            }
        }
    }

    #[test]
    fn f16_overflow_and_underflow() {
        assert_eq!(f32_to_f16(1.0e6), 0x7c00); // +Inf
        assert_eq!(f32_to_f16(-1.0e6), 0xfc00); // -Inf
        assert_eq!(f32_to_f16(1.0e-10), 0x0000); // underflow to +0
    }
}
