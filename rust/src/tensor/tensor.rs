//! The owned tensor type used for parameter groups.

use super::dtype::{bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16, DType};

/// Errors from tensor construction and conversion.
#[derive(Debug, thiserror::Error)]
pub enum TensorError {
    #[error("data length {got} does not match shape {shape:?} x dtype {dtype} = {want} bytes")]
    LengthMismatch {
        got: usize,
        want: usize,
        shape: Vec<usize>,
        dtype: DType,
    },
    #[error("dtype mismatch: expected {expected}, got {got}")]
    DTypeMismatch { expected: DType, got: DType },
    #[error("shape mismatch: {a:?} vs {b:?}")]
    ShapeMismatch { a: Vec<usize>, b: Vec<usize> },
    #[error("cannot convert dtype {from} to {to}")]
    BadConversion { from: DType, to: DType },
}

/// A dense, contiguous, little-endian tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    dtype: DType,
    shape: Vec<usize>,
    /// Raw little-endian element bytes, length = numel * dtype.size().
    data: Vec<u8>,
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Tensor({} {:?}, {} bytes)",
            self.dtype,
            self.shape,
            self.data.len()
        )
    }
}

impl Tensor {
    /// Construct from raw little-endian bytes.
    pub fn from_bytes(
        dtype: DType,
        shape: Vec<usize>,
        data: Vec<u8>,
    ) -> Result<Tensor, TensorError> {
        let want = shape.iter().product::<usize>() * dtype.size();
        if data.len() != want {
            return Err(TensorError::LengthMismatch {
                got: data.len(),
                want,
                shape,
                dtype,
            });
        }
        Ok(Tensor { dtype, shape, data })
    }

    /// Construct an f32 tensor from values.
    pub fn from_f32(shape: Vec<usize>, values: Vec<f32>) -> Result<Tensor, TensorError> {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor::from_bytes(DType::F32, shape, data)
    }

    /// Construct an i64 tensor from values (used for sparse indices).
    pub fn from_i64(shape: Vec<usize>, values: Vec<i64>) -> Result<Tensor, TensorError> {
        let mut data = Vec::with_capacity(values.len() * 8);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor::from_bytes(DType::I64, shape, data)
    }

    /// All-zeros tensor.
    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Tensor {
        let len = shape.iter().product::<usize>() * dtype.size();
        Tensor {
            dtype,
            shape,
            data: vec![0u8; len],
        }
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.data
    }

    /// Typed f32 view (only valid for DType::F32).
    pub fn as_f32(&self) -> Result<&[f32], TensorError> {
        if self.dtype != DType::F32 {
            return Err(TensorError::DTypeMismatch {
                expected: DType::F32,
                got: self.dtype,
            });
        }
        // Data is a Vec<u8>; alignment of Vec<u8> is 1, so we cannot
        // transmute safely in general. We guarantee alignment by checking.
        let ptr = self.data.as_ptr();
        if (ptr as usize) % std::mem::align_of::<f32>() == 0 {
            let slice =
                unsafe { std::slice::from_raw_parts(ptr as *const f32, self.data.len() / 4) };
            Ok(slice)
        } else {
            // Extremely rare (Vec<u8> from global alloc is well-aligned),
            // but fall back correctly by erroring; callers use to_f32_vec.
            Err(TensorError::BadConversion {
                from: self.dtype,
                to: DType::F32,
            })
        }
    }

    /// Decode elements to f32 regardless of float dtype.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>, TensorError> {
        let n = self.numel();
        let mut out = Vec::with_capacity(n);
        match self.dtype {
            DType::F32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
            }
            DType::F64 => {
                for c in self.data.chunks_exact(8) {
                    out.push(f64::from_le_bytes(c.try_into().unwrap()) as f32);
                }
            }
            DType::BF16 => {
                for c in self.data.chunks_exact(2) {
                    out.push(bf16_to_f32(u16::from_le_bytes(c.try_into().unwrap())));
                }
            }
            DType::F16 => {
                for c in self.data.chunks_exact(2) {
                    out.push(f16_to_f32(u16::from_le_bytes(c.try_into().unwrap())));
                }
            }
            dt => {
                return Err(TensorError::BadConversion {
                    from: dt,
                    to: DType::F32,
                })
            }
        }
        Ok(out)
    }

    /// Decode elements to i64 (integer dtypes only).
    pub fn to_i64_vec(&self) -> Result<Vec<i64>, TensorError> {
        let mut out = Vec::with_capacity(self.numel());
        match self.dtype {
            DType::I64 => {
                for c in self.data.chunks_exact(8) {
                    out.push(i64::from_le_bytes(c.try_into().unwrap()));
                }
            }
            DType::I32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(i32::from_le_bytes(c.try_into().unwrap()) as i64);
                }
            }
            DType::U8 | DType::Bool => {
                for &b in &self.data {
                    out.push(b as i64);
                }
            }
            dt => {
                return Err(TensorError::BadConversion {
                    from: dt,
                    to: DType::I64,
                })
            }
        }
        Ok(out)
    }

    /// Re-encode f32 values into this dtype (float dtypes only).
    pub fn from_f32_as(
        dtype: DType,
        shape: Vec<usize>,
        values: &[f32],
    ) -> Result<Tensor, TensorError> {
        let mut data = Vec::with_capacity(values.len() * dtype.size());
        match dtype {
            DType::F32 => {
                for v in values {
                    data.extend_from_slice(&v.to_le_bytes());
                }
            }
            DType::F64 => {
                for v in values {
                    data.extend_from_slice(&(*v as f64).to_le_bytes());
                }
            }
            DType::BF16 => {
                for v in values {
                    data.extend_from_slice(&f32_to_bf16(*v).to_le_bytes());
                }
            }
            DType::F16 => {
                for v in values {
                    data.extend_from_slice(&f32_to_f16(*v).to_le_bytes());
                }
            }
            dt => {
                return Err(TensorError::BadConversion {
                    from: DType::F32,
                    to: dt,
                })
            }
        }
        Tensor::from_bytes(dtype, shape, data)
    }

    /// Cast to a different float dtype (identity if same).
    pub fn cast(&self, dtype: DType) -> Result<Tensor, TensorError> {
        if dtype == self.dtype {
            return Ok(self.clone());
        }
        let values = self.to_f32_vec()?;
        Tensor::from_f32_as(dtype, self.shape.clone(), &values)
    }

    /// Reshape without copying data (element counts must match).
    pub fn reshape(&self, shape: Vec<usize>) -> Result<Tensor, TensorError> {
        if shape.iter().product::<usize>() != self.numel() {
            return Err(TensorError::ShapeMismatch {
                a: self.shape.clone(),
                b: shape,
            });
        }
        Ok(Tensor {
            dtype: self.dtype,
            shape,
            data: self.data.clone(),
        })
    }

    /// Take rows [0, keep) along the first axis (used by the paper's
    /// "remove sentinel embeddings" Trim operation).
    pub fn take_rows(&self, keep: usize) -> Result<Tensor, TensorError> {
        let rows = *self.shape.first().unwrap_or(&0);
        if keep > rows {
            return Err(TensorError::ShapeMismatch {
                a: self.shape.clone(),
                b: vec![keep],
            });
        }
        let row_bytes = if rows == 0 { 0 } else { self.data.len() / rows };
        let mut shape = self.shape.clone();
        shape[0] = keep;
        Tensor::from_bytes(self.dtype, shape, self.data[..keep * row_bytes].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_views() {
        let t = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.numel(), 6);
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.as_f32().unwrap(), &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.to_f32_vec().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn length_validation() {
        assert!(Tensor::from_bytes(DType::F32, vec![2, 2], vec![0u8; 15]).is_err());
        assert!(Tensor::from_bytes(DType::F32, vec![2, 2], vec![0u8; 16]).is_ok());
    }

    #[test]
    fn casts_roundtrip_through_bf16() {
        let vals = vec![0.0f32, 1.0, -0.5, 100.0];
        let t = Tensor::from_f32(vec![4], vals.clone()).unwrap();
        let b = t.cast(DType::BF16).unwrap();
        assert_eq!(b.nbytes(), 8);
        let back = b.cast(DType::F32).unwrap();
        // These values are bf16-representable, so exact.
        assert_eq!(back.to_f32_vec().unwrap(), vals);
    }

    #[test]
    fn reshape_and_take_rows() {
        let t = Tensor::from_f32(vec![4, 2], (0..8).map(|x| x as f32).collect()).unwrap();
        let r = t.reshape(vec![2, 4]).unwrap();
        assert_eq!(r.shape(), &[2, 4]);
        assert!(t.reshape(vec![3, 3]).is_err());
        let trimmed = t.take_rows(2).unwrap();
        assert_eq!(trimmed.shape(), &[2, 2]);
        assert_eq!(trimmed.to_f32_vec().unwrap(), vec![0., 1., 2., 3.]);
        assert!(t.take_rows(5).is_err());
    }

    #[test]
    fn i64_tensors() {
        let t = Tensor::from_i64(vec![3], vec![-1, 0, 1 << 40]).unwrap();
        assert_eq!(t.to_i64_vec().unwrap(), vec![-1, 0, 1 << 40]);
        assert!(t.to_f32_vec().is_err());
    }

    #[test]
    fn zeros() {
        let t = Tensor::zeros(DType::BF16, vec![10]);
        assert_eq!(t.nbytes(), 20);
        assert!(t.to_f32_vec().unwrap().iter().all(|&v| v == 0.0));
    }
}
