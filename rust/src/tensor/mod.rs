//! Tensor core: dtypes, shapes, and the in-memory tensor type that
//! parameter groups are represented as throughout Git-Theta.
//!
//! A checkpoint is an ordered map of parameter-group name → [`Tensor`].
//! Tensors own a contiguous little-endian byte buffer plus a dtype and
//! shape; numeric operations used by updates/merges promote to f64
//! accumulation where it matters (averaging) and otherwise stay in f32.

mod dtype;
mod ops;
mod tensor;

pub use dtype::{bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16, DType};
pub use ops::{
    add, add_scalar, allclose, axpy, div, euclidean_distance, fisher_average, mul, scale, sub,
    weighted_average,
};
pub use tensor::{Tensor, TensorError};
