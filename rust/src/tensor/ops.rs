//! Elementwise numeric operations on tensors.
//!
//! These are the pure-Rust reference paths; `mlops/` routes large inputs
//! through the AOT-compiled Pallas kernels and uses these for fallback
//! and cross-checking.

use super::tensor::{Tensor, TensorError};

fn check_same(a: &Tensor, b: &Tensor) -> Result<(), TensorError> {
    if a.shape() != b.shape() {
        return Err(TensorError::ShapeMismatch {
            a: a.shape().to_vec(),
            b: b.shape().to_vec(),
        });
    }
    Ok(())
}

/// a + b, computed in f32, result in a's dtype.
pub fn add(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    check_same(a, b)?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let out: Vec<f32> = av.iter().zip(&bv).map(|(x, y)| x + y).collect();
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// a - b, computed in f32, result in a's dtype.
pub fn sub(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    check_same(a, b)?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let out: Vec<f32> = av.iter().zip(&bv).map(|(x, y)| x - y).collect();
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// alpha * a, result in a's dtype.
pub fn scale(a: &Tensor, alpha: f32) -> Result<Tensor, TensorError> {
    let av = a.to_f32_vec()?;
    let out: Vec<f32> = av.iter().map(|x| x * alpha).collect();
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// a + alpha * b.
pub fn axpy(a: &Tensor, alpha: f32, b: &Tensor) -> Result<Tensor, TensorError> {
    check_same(a, b)?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let out: Vec<f32> = av.iter().zip(&bv).map(|(x, y)| x + alpha * y).collect();
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// a * b elementwise (Hadamard product), computed in f32, result in
/// a's dtype. Used by importance-weighted merges (Fisher averaging).
pub fn mul(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    check_same(a, b)?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let out: Vec<f32> = av.iter().zip(&bv).map(|(x, y)| x * y).collect();
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// a / b elementwise, computed in f32, result in a's dtype. IEEE
/// semantics: division by zero yields ±inf/NaN rather than erroring —
/// callers guarding with an epsilon (Fisher) never hit it.
pub fn div(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    check_same(a, b)?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let out: Vec<f32> = av.iter().zip(&bv).map(|(x, y)| x / y).collect();
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// a + s elementwise (scalar broadcast), result in a's dtype.
pub fn add_scalar(a: &Tensor, s: f32) -> Result<Tensor, TensorError> {
    let av = a.to_f32_vec()?;
    let out: Vec<f32> = av.iter().map(|x| x + s).collect();
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// Weighted average of k tensors (f64 accumulation) — the paper's
/// parameter-averaging merge (Wortsman et al. 2022; Choshen et al. 2022b).
pub fn weighted_average(tensors: &[&Tensor], weights: &[f64]) -> Result<Tensor, TensorError> {
    assert!(!tensors.is_empty() && tensors.len() == weights.len());
    for t in &tensors[1..] {
        check_same(tensors[0], t)?;
    }
    let total: f64 = weights.iter().sum();
    let n = tensors[0].numel();
    let mut acc = vec![0f64; n];
    for (t, &w) in tensors.iter().zip(weights) {
        let v = t.to_f32_vec()?;
        for (a, x) in acc.iter_mut().zip(&v) {
            *a += w * *x as f64;
        }
    }
    let out: Vec<f32> = acc.iter().map(|a| (*a / total) as f32).collect();
    Tensor::from_f32_as(tensors[0].dtype(), tensors[0].shape().to_vec(), &out)
}

/// Fisher-style importance-weighted average of two branches against a
/// common ancestor (Matena & Raffel 2022): each branch's per-element
/// importance is its squared movement from the ancestor (+`eps` so
/// elements neither branch moved average uniformly). One fused pass
/// with f64 accumulation and no intermediate tensors — the merge
/// driver calls this once per conflicted group, so the k-tensor
/// op-chain equivalent would cost several full-tensor copies here.
pub fn fisher_average(
    a: &Tensor,
    b: &Tensor,
    base: &Tensor,
    eps: f64,
) -> Result<Tensor, TensorError> {
    check_same(a, b)?;
    check_same(a, base)?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let cv = base.to_f32_vec()?;
    let mut out = Vec::with_capacity(av.len());
    for ((&x, &y), &c) in av.iter().zip(&bv).zip(&cv) {
        let fa = (x as f64 - c as f64).powi(2) + eps;
        let fb = (y as f64 - c as f64).powi(2) + eps;
        out.push(((fa * x as f64 + fb * y as f64) / (fa + fb)) as f32);
    }
    Tensor::from_f32_as(a.dtype(), a.shape().to_vec(), &out)
}

/// Euclidean distance ||a - b||_2 in f64.
pub fn euclidean_distance(a: &Tensor, b: &Tensor) -> Result<f64, TensorError> {
    check_same(a, b)?;
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    let mut acc = 0f64;
    for (x, y) in av.iter().zip(&bv) {
        let d = *x as f64 - *y as f64;
        acc += d * d;
    }
    Ok(acc.sqrt())
}

/// numpy-style allclose: |a - b| <= atol + rtol * |b| elementwise.
///
/// This is the paper's safety check for parameter groups whose LSH
/// distance estimate falls in the ambiguous [1e-8, 1e-6] band.
pub fn allclose(a: &Tensor, b: &Tensor, rtol: f64, atol: f64) -> Result<bool, TensorError> {
    if a.shape() != b.shape() {
        return Ok(false);
    }
    let av = a.to_f32_vec()?;
    let bv = b.to_f32_vec()?;
    for (x, y) in av.iter().zip(&bv) {
        let (x, y) = (*x as f64, *y as f64);
        if x.is_nan() || y.is_nan() {
            return Ok(false);
        }
        if (x - y).abs() > atol + rtol * y.abs() {
            return Ok(false);
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    fn t(vals: &[f32]) -> Tensor {
        Tensor::from_f32(vec![vals.len()], vals.to_vec()).unwrap()
    }

    #[test]
    fn basic_arithmetic() {
        let a = t(&[1., 2., 3.]);
        let b = t(&[10., 20., 30.]);
        assert_eq!(add(&a, &b).unwrap().to_f32_vec().unwrap(), vec![11., 22., 33.]);
        assert_eq!(sub(&b, &a).unwrap().to_f32_vec().unwrap(), vec![9., 18., 27.]);
        assert_eq!(scale(&a, 2.0).unwrap().to_f32_vec().unwrap(), vec![2., 4., 6.]);
        assert_eq!(
            axpy(&a, 0.5, &b).unwrap().to_f32_vec().unwrap(),
            vec![6., 12., 18.]
        );
        assert_eq!(
            mul(&a, &b).unwrap().to_f32_vec().unwrap(),
            vec![10., 40., 90.]
        );
        assert_eq!(div(&b, &a).unwrap().to_f32_vec().unwrap(), vec![10.; 3]);
        assert_eq!(
            add_scalar(&a, 0.5).unwrap().to_f32_vec().unwrap(),
            vec![1.5, 2.5, 3.5]
        );
    }

    #[test]
    fn div_by_zero_is_ieee() {
        let a = t(&[1., -1., 0.]);
        let z = t(&[0., 0., 0.]);
        let out = div(&a, &z).unwrap().to_f32_vec().unwrap();
        assert_eq!(out[0], f32::INFINITY);
        assert_eq!(out[1], f32::NEG_INFINITY);
        assert!(out[2].is_nan());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = t(&[1., 2.]);
        let b = t(&[1., 2., 3.]);
        assert!(add(&a, &b).is_err());
    }

    #[test]
    fn average_two_and_three() {
        let a = t(&[0., 0.]);
        let b = t(&[2., 4.]);
        let avg = weighted_average(&[&a, &b], &[1.0, 1.0]).unwrap();
        assert_eq!(avg.to_f32_vec().unwrap(), vec![1., 2.]);
        let c = t(&[4., 8.]);
        let avg3 = weighted_average(&[&a, &b, &c], &[1.0, 1.0, 1.0]).unwrap();
        assert_eq!(avg3.to_f32_vec().unwrap(), vec![2., 4.]);
        // Weighted.
        let w = weighted_average(&[&a, &b], &[3.0, 1.0]).unwrap();
        assert_eq!(w.to_f32_vec().unwrap(), vec![0.5, 1.0]);
    }

    #[test]
    fn fisher_average_weights_by_movement() {
        let base = t(&[0.0, 0.0, 1.0]);
        let a = t(&[2.0, 0.0, 1.0]); // moved elem 0 hard
        let b = t(&[0.1, 3.0, 1.0]); // moved elem 1 hard
        let out = fisher_average(&a, &b, &base, 1e-12).unwrap();
        let v = out.to_f32_vec().unwrap();
        assert!(v[0] > 1.9, "{v:?}"); // a's movement dominates
        assert!(v[1] > 2.9, "{v:?}"); // b's movement dominates
        assert_eq!(v[2], 1.0); // untouched element: uniform average
        // Shape mismatches are rejected like every other elementwise op.
        assert!(fisher_average(&a, &b, &t(&[0.0]), 1e-12).is_err());
    }

    #[test]
    fn distance_and_allclose() {
        let a = t(&[0., 3.]);
        let b = t(&[4., 0.]);
        assert!((euclidean_distance(&a, &b).unwrap() - 5.0).abs() < 1e-12);
        assert!(allclose(&a, &a, 1e-5, 1e-8).unwrap());
        assert!(!allclose(&a, &b, 1e-5, 1e-8).unwrap());
        let c = t(&[0., 3.0 + 1e-7]);
        assert!(allclose(&a, &c, 1e-5, 1e-8).unwrap());
    }

    #[test]
    fn allclose_nan_is_not_close() {
        let a = t(&[f32::NAN]);
        assert!(!allclose(&a, &a, 1e-5, 1e-8).unwrap());
    }

    #[test]
    fn ops_preserve_dtype() {
        let a = t(&[1.0, 2.0]).cast(DType::BF16).unwrap();
        let b = t(&[1.0, 2.0]).cast(DType::BF16).unwrap();
        let s = add(&a, &b).unwrap();
        assert_eq!(s.dtype(), DType::BF16);
        assert_eq!(s.to_f32_vec().unwrap(), vec![2.0, 4.0]);
    }
}
