#!/usr/bin/env python3
"""Bench regression gate: compare BENCH_*.json against a committed baseline.

Usage: bench_gate.py scripts/bench_baseline.json

The baseline file lists tracked metrics as
    {"file": "BENCH_transfer.json", "path": "runs[1].up_round_trips",
     "baseline": 2, "direction": "lower", "tolerance": 0.2, "note": "..."}

`direction` says which way is better ("lower" or "higher"); a run fails
the gate when a metric is worse than baseline by more than `tolerance`.
For ratio-scale metrics — numeric baselines with |baseline| <= 1.0 —
`tolerance` is an absolute delta (a relative rule on a near-zero
baseline is either meaninglessly tight or vacuous at 0); for everything
else it is relative. A `baseline` of null records the metric
advisorily — its current value is printed so a later PR can commit
it — without gating.
"""

import json
import re
import sys


def get_path(doc, path):
    """Resolve 'runs[1].up_round_trips'-style paths."""
    cur = doc
    for part in path.split("."):
        m = re.fullmatch(r"([A-Za-z_][A-Za-z0-9_]*)(?:\[(\d+)\])?", part)
        if not m:
            raise KeyError(f"bad path segment '{part}'")
        cur = cur[m.group(1)]
        if m.group(2) is not None:
            cur = cur[int(m.group(2))]
    return cur


def main():
    if len(sys.argv) != 2:
        print(__doc__.strip())
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)

    docs = {}
    failures = []
    advisories = []
    for metric in baseline["metrics"]:
        fname, path = metric["file"], metric["path"]
        if fname not in docs:
            try:
                with open(fname) as f:
                    docs[fname] = json.load(f)
            except FileNotFoundError:
                failures.append(f"{fname}: missing (did its bench smoke run?)")
                docs[fname] = None
        doc = docs[fname]
        if doc is None:
            continue
        try:
            value = get_path(doc, path)
        except (KeyError, IndexError, TypeError) as e:
            failures.append(f"{fname}:{path}: unresolvable ({e})")
            continue
        base = metric.get("baseline")
        if base is None:
            advisories.append(f"{fname}:{path} = {value} (no baseline committed yet)")
            continue
        tol = metric.get("tolerance", 0.2)
        direction = metric.get("direction", "lower")
        if abs(base) <= 1.0:
            # Ratio-scale metric: absolute-delta threshold.
            if direction == "lower":
                worse = value > base + tol
            else:
                worse = value < base - tol
            rule = f"abs tol {tol}"
        else:
            if direction == "lower":
                worse = value > base * (1 + tol)
            else:
                worse = value < base * (1 - tol)
            rule = f"tol {int(tol * 100)}%"
        verdict = "FAIL" if worse else "ok"
        print(f"  [{verdict}] {fname}:{path} = {value} (baseline {base}, {direction} "
              f"is better, {rule})")
        if worse:
            failures.append(
                f"{fname}:{path} regressed: {value} vs baseline {base} "
                f"(worse than {rule}) — {metric.get('note', '')}")

    for line in advisories:
        print(f"  [note] {line}")
    if failures:
        print("bench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
