#!/usr/bin/env bash
# Repo gate: formatting, lints on the transfer subsystem, build, tests.
# Usage: scripts/check.sh   (run from anywhere inside the repository)
set -euo pipefail

cd "$(dirname "$0")/.."

# The paper-reproduction driver supplies the Cargo manifest (it wires
# the environment-specific `xla` PJRT dependency). Without it the cargo
# checks cannot run; skip explicitly instead of failing every build.
if [ ! -f Cargo.toml ]; then
    echo "::warning::no Cargo.toml at the repo root (driver-supplied manifest absent); cargo checks skipped"
    echo "note: no Cargo.toml at the repo root (driver-supplied manifest absent);"
    echo "      skipping cargo-based checks in this environment."
    exit 0
fi

echo "==> cargo fmt --check"
if ! cargo fmt --check; then
    echo "error: formatting drift — run 'cargo fmt' and re-commit" >&2
    exit 1
fi

# Clippy warnings are denied in the modules that have had their lint
# pass (the transfer subsystem and its benchkit harness); the rest of
# the crate reports but does not fail until the burn-down (ROADMAP.md).
echo "==> cargo clippy (deny warnings in lfs/ and benchkit/transfer)"
clippy_out=$(cargo clippy --release --message-format=short 2>&1 || true)
echo "$clippy_out"
if echo "$clippy_out" | grep -E 'src/(lfs/|benchkit/transfer)' | grep -q 'warning'; then
    echo "error: clippy warnings in the transfer subsystem" >&2
    exit 1
fi
if echo "$clippy_out" | grep -q '^error'; then
    echo "error: clippy failed to compile the crate" >&2
    exit 1
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# Smoke the checkout-engine ablation (tiny configuration): exercises
# snapshotting, both decode paths, and the per-depth identity check
# end-to-end through the real CLI.
echo "==> bench checkout smoke"
cargo run --release --quiet -- bench checkout 10 2 8192
test -f BENCH_checkout.json || {
    echo "error: bench checkout did not write BENCH_checkout.json" >&2
    exit 1
}

# Smoke the merge-engine ablation (tiny configuration): classification,
# batched prefetch, parallel resolution, change-skipping, and the
# per-sample merged-output parity assertion, through the real CLI.
echo "==> bench merge smoke"
cargo run --release --quiet -- bench merge 4 12 2048
test -f BENCH_merge.json || {
    echo "error: bench merge did not write BENCH_merge.json" >&2
    exit 1
}

# Smoke the chain-aware delta protocol in isolation (tiny config),
# both directions: push (chain-prefix negotiation, v2 delta pack
# against a held remote base) and fetch (a clone holding the base
# advertises its chains, the server plans deltas through its plan
# cache), with byte-verified reconstruction on each receiving store.
# The full transfer smoke below re-runs both at the locked 64x8192
# shape.
echo "==> bench transfer --delta smoke"
cargo run --release --quiet -- bench transfer --delta 8 2048

# Smoke the transfer ablation (tiny configuration): per-object vs
# packed vs http transport, plus the +resume injected-fault sample
# (fault proxy kills the pack stream halfway; the retry must resume).
echo "==> bench transfer smoke"
cargo run --release --quiet -- bench transfer 20 2048
test -f BENCH_transfer.json || {
    echo "error: bench transfer did not write BENCH_transfer.json" >&2
    exit 1
}

# Smoke the collaboration scenario (4 actors x 40 ops, pinned seed,
# one injected mid-pack fetch kill): concurrent clones against one
# served hub, quiesce, and the full convergence proof — byte-identical
# checkouts, fresh-clone reproduction, hub store verify. Exits nonzero
# on divergence and prints the replay seed.
echo "==> bench scenario smoke"
cargo run --release --quiet -- bench scenario 4 40 3405691582 1
test -f BENCH_scenario.json || {
    echo "error: bench scenario did not write BENCH_scenario.json" >&2
    exit 1
}

# Smoke the chaos suite (default 4 actors x 3 objects, pinned seed):
# overload the undersized hub until it sheds, cut a stalled upload with
# the request budget, then converge an actor fleet through injected 503
# bursts and a mid-upload stall. Exits nonzero unless stores converge
# byte-identically, every fault fired, and shutdown drains clean; prints
# the replay seed on entry.
echo "==> bench chaos smoke"
cargo run --release --quiet -- bench chaos
test -f BENCH_chaos.json || {
    echo "error: bench chaos did not write BENCH_chaos.json" >&2
    exit 1
}

# Smoke the replication suite (default 8 objects, pinned seed): a
# quorum-degraded push over a 2-of-3 replica set that anti-entropy
# repair converges byte-identically, then a fetch that survives a
# mid-pack mirror kill by failing over and resuming the partial. Exits
# nonzero unless both phases converge with zero checksum failures.
echo "==> bench replicate smoke"
cargo run --release --quiet -- bench replicate
test -f BENCH_replicate.json || {
    echo "error: bench replicate did not write BENCH_replicate.json" >&2
    exit 1
}

# Regression gate: BENCH_*.json counters vs the committed baseline
# snapshot (scripts/bench_baseline.json). Counter metrics are exact
# protocol invariants and fail the build when >20% worse; time metrics
# stay advisory until enough CI history exists to lock them. The JSON
# files are uploaded as CI artifacts by .github/workflows/ci.yml.
echo "==> bench regression gate"
if command -v python3 >/dev/null 2>&1; then
    python3 scripts/bench_gate.py scripts/bench_baseline.json
else
    echo "::warning::python3 unavailable; bench regression gate skipped"
fi

echo "==> OK"
